//! Threaded message-passing cluster + α–β communication cost model.
//!
//! [`Cluster::run`] spawns one OS thread per simulated node and hands
//! each a [`Comm`] endpoint (send/recv/barrier over std mpsc channels) —
//! enough to execute genuinely distributed protocols (the full LB
//! pipeline in [`crate::distributed`] and the stage-1 handshake in
//! [`super::protocol`]) without any external runtime.
//!
//! Failure semantics: protocol receives ([`Comm::recv_tagged`],
//! [`Comm::barrier`]) return typed errors instead of panicking, so a
//! dead or partitioned peer propagates as a recoverable
//! [`CommError`] that the epoch/restart layer in
//! `crate::distributed::epoch` turns into a membership change. Three
//! mechanisms support that layer:
//!
//! * **epochs** — every message is stamped with the sender's membership
//!   epoch; receives only match same-epoch messages, stale ones are
//!   dropped (and counted, see [`Comm::stale_drops`]) so a restarted
//!   pipeline stage can never consume pre-fault traffic;
//! * **control namespace** — tags whose top byte is `0x7F`
//!   ([`CTRL_NS`]) bypass epoch filtering entirely; the failure
//!   detector and epoch-declaration protocol run over them;
//! * **groups** — [`Comm::enter_group`] narrows the endpoint to a
//!   survivor subset with dense ranks `0..m`, so the unchanged stage
//!   protocols run on the reduced cluster without renumbering logic.
//!
//! [`NetModel`] converts message/byte counts into seconds the way the
//! strong-scaling analysis needs: `t = α·msgs + β·bytes`, with
//! intra-node traffic discounted (shared memory vs NIC).

use std::collections::HashSet;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::fault::FaultPlan;

/// Tag namespace (top byte) reserved for membership/failure control
/// traffic: messages carrying these tags bypass epoch filtering (an
/// epoch declaration must be deliverable across the very epoch change
/// it announces).
pub const CTRL_NS: u32 = 0x7F00_0000;

/// Whether `tag` lives in the control namespace.
pub const fn is_ctrl_tag(tag: u32) -> bool {
    tag & 0xFF00_0000 == CTRL_NS
}

/// A message between simulated nodes: (source, tag, epoch, payload).
/// `from` is always the sender's **world** rank; group-mode receives
/// translate it to the dense group rank on delivery.
#[derive(Debug, Clone, PartialEq)]
pub struct Msg {
    pub from: u32,
    pub tag: u32,
    pub epoch: u32,
    pub data: Vec<u8>,
}

/// Why a blocking receive returned without a message. A dead peer set
/// (every sender endpoint dropped) is a *distinct* outcome from a slow
/// one: protocols treat [`RecvError::Disconnected`] as fatal
/// immediately instead of burning the full timeout waiting for a
/// message that can never arrive.
///
/// Scope caveat: inside a [`Cluster`], every node holds sender clones
/// to every inbox (including its own loopback), so `Disconnected`
/// fires only when the *whole* cluster is torn down — a single dead
/// peer among survivors still surfaces as `Timeout` (which is why the
/// failure detector in `distributed::epoch` is heartbeat-based). The
/// distinct outcome matters for endpoints whose senders genuinely all
/// dropped, e.g. teardown races and embedding `Comm` outside
/// `Cluster::run`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecvError {
    /// No message arrived within the timeout; peers may just be slow.
    Timeout,
    /// All sender endpoints are gone — nothing can ever arrive.
    Disconnected,
}

/// A protocol phase ([`Comm::recv_tagged`]) that could not complete.
/// Both variants carry the partial delivery so callers (the barrier,
/// the failure detector) can tell *who* went missing; the messages are
/// intentionally not re-parked — after a failed phase the pipeline
/// restarts under a new epoch and they would be stale anyway.
#[derive(Debug, Clone, PartialEq)]
pub enum CommError {
    /// The phase timed out with `got.len() < want` messages delivered.
    Timeout { tag: u32, want: usize, got: Vec<Msg> },
    /// Every sender endpoint dropped mid-phase (whole-cluster
    /// teardown).
    Disconnected { tag: u32, want: usize, got: Vec<Msg> },
    /// A delivered frame failed structural decode (short payload or an
    /// untrusted length that overran it). Raised by the protocol
    /// decoders, not the transport: the simulated network never
    /// corrupts, but a version-skewed or buggy peer can, and decode
    /// must degrade to an error the recovery layer sees — not a panic
    /// that poisons the node thread.
    Corrupt { tag: u32, from: u32 },
}

impl CommError {
    /// The ranks (in the caller's current rank space) whose messages
    /// did arrive before the failure.
    pub fn arrived(&self) -> Vec<u32> {
        match self {
            CommError::Timeout { got, .. } | CommError::Disconnected { got, .. } => {
                got.iter().map(|m| m.from).collect()
            }
            CommError::Corrupt { .. } => Vec::new(),
        }
    }
}

impl std::fmt::Display for CommError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CommError::Timeout { tag, want, got } => write!(
                f,
                "phase {tag:#x} timed out with {}/{want} messages delivered",
                got.len()
            ),
            CommError::Disconnected { tag, want, got } => write!(
                f,
                "cluster disconnected in phase {tag:#x} with {}/{want} messages delivered",
                got.len()
            ),
            CommError::Corrupt { tag, from } => {
                write!(f, "phase {tag:#x} received a corrupt frame from rank {from}")
            }
        }
    }
}

impl std::error::Error for CommError {}

/// A barrier that did not complete: `missing` names the peers (in the
/// caller's current rank space) that never announced arrival.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BarrierError {
    pub tag: u32,
    pub missing: Vec<u32>,
}

impl std::fmt::Display for BarrierError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "barrier {:#x} timed out; missing ranks {:?}", self.tag, self.missing)
    }
}

impl std::error::Error for BarrierError {}

/// Per-node communication endpoint.
pub struct Comm {
    /// Rank in the current addressing space: the world rank normally,
    /// the dense group index inside [`Comm::enter_group`].
    pub rank: u32,
    /// Size of the current addressing space.
    pub n: usize,
    senders: Vec<Sender<Msg>>,
    inbox: Receiver<Msg>,
    /// Out-of-phase messages put aside by [`Comm::recv_tagged`]: a fast
    /// peer may already be sending the next protocol phase while this
    /// node still drains the current one. Stored with world `from` and
    /// original epoch.
    pending: Vec<Msg>,
    /// Immutable identity (survives group narrowing).
    world_rank: u32,
    world_n: usize,
    /// Active survivor group: sorted world ranks, `None` = full world.
    group: Option<Vec<u32>>,
    /// Current membership epoch; bumped by the recovery protocol.
    epoch: u32,
    /// Messages from dead epochs dropped instead of delivered.
    stale_drops: u64,
    /// Messages from *future* epochs parked before this node caught up
    /// (a recovered peer racing ahead of a laggard).
    future_parks: u64,
    /// Barriers that timed out on this endpoint (each one hands
    /// control to the recovery layer).
    barrier_timeouts: u64,
    /// Patience for protocol receives; [`Comm::TIMEOUT`] unless a
    /// fault plan shortens it for detection.
    patience: Duration,
    /// Installed chaos schedule (partition cuts apply in `send`).
    plan: Option<Arc<FaultPlan>>,
    /// Partition clock: the LB round the driver most recently entered.
    fault_clock: u64,
    /// Debug-build registry documenting the barrier tag-uniqueness
    /// contract (see [`Comm::barrier`]).
    barrier_tags: HashSet<u64>,
}

impl Comm {
    /// Default patience for protocol receives: long enough that only a
    /// genuine deadlock (not scheduler jitter) trips it.
    pub const TIMEOUT: Duration = Duration::from_secs(30);

    /// Build an endpoint from raw channel halves (used by [`Cluster`]
    /// and by unit tests that need to simulate dead peers).
    fn new(rank: u32, n: usize, senders: Vec<Sender<Msg>>, inbox: Receiver<Msg>) -> Comm {
        Comm {
            rank,
            n,
            senders,
            inbox,
            pending: Vec::new(),
            world_rank: rank,
            world_n: n,
            group: None,
            epoch: 0,
            stale_drops: 0,
            future_parks: 0,
            barrier_timeouts: 0,
            patience: Self::TIMEOUT,
            plan: None,
            fault_clock: 0,
            barrier_tags: HashSet::new(),
        }
    }

    /// This endpoint's world identity (stable across group narrowing).
    pub fn world_rank(&self) -> u32 {
        self.world_rank
    }

    /// World cluster size (stable across group narrowing).
    pub fn world_n(&self) -> usize {
        self.world_n
    }

    /// Current membership epoch.
    pub fn epoch(&self) -> u32 {
        self.epoch
    }

    /// How many wrong-epoch messages have been dropped so far (the
    /// counter behind the "stale traffic is never silently matched"
    /// contract).
    pub fn stale_drops(&self) -> u64 {
        self.stale_drops
    }

    /// How many future-epoch messages were parked before this node
    /// adopted their epoch (see [`Comm::set_epoch`]).
    pub fn future_parks(&self) -> u64 {
        self.future_parks
    }

    /// How many barriers timed out on this endpoint.
    pub fn barrier_timeouts(&self) -> u64 {
        self.barrier_timeouts
    }

    /// Count `n` wrong-epoch drops, mirrored into the process-global
    /// registry (`comm.stale_drops`) for end-of-run dumps.
    fn count_stale(&mut self, n: u64) {
        if n == 0 {
            return;
        }
        self.stale_drops += n;
        crate::obs::counter!("comm.stale_drops").add(n);
    }

    /// Park an out-of-phase message, counting future-epoch arrivals.
    fn park(&mut self, m: Msg) {
        if !is_ctrl_tag(m.tag) && m.epoch > self.epoch {
            self.future_parks += 1;
            crate::obs::counter!("comm.future_parks").inc();
        }
        self.pending.push(m);
    }

    /// Patience protocol receives should use (shortened under an
    /// active fault plan so detection beats the 30 s default).
    pub fn patience(&self) -> Duration {
        self.patience
    }

    pub fn set_patience(&mut self, patience: Duration) {
        self.patience = patience;
    }

    /// Advance the partition clock (the driver calls this on entering
    /// each LB round's pipeline; [`FaultPlan`] partition events are
    /// keyed to it).
    pub fn set_fault_round(&mut self, round: u64) {
        self.fault_clock = round;
    }

    /// Adopt membership epoch `epoch` and drain the pending buffer of
    /// now-stale messages so a restarted pipeline stage can never
    /// consume pre-fault traffic. Returns how many were dropped (also
    /// added to [`Comm::stale_drops`]); control-namespace messages are
    /// kept regardless of epoch.
    pub fn set_epoch(&mut self, epoch: u32) -> usize {
        self.epoch = epoch;
        let before = self.pending.len();
        self.pending.retain(|m| is_ctrl_tag(m.tag) || m.epoch >= epoch);
        let dropped = before - self.pending.len();
        self.count_stale(dropped as u64);
        dropped
    }

    /// Narrow the endpoint to a survivor subset: `members` are sorted
    /// world ranks that must include this node. Until
    /// [`Comm::leave_group`], `rank`/`n` are the dense group index and
    /// size, sends address group ranks, and delivered messages carry
    /// group-translated `from` fields — so the stage protocols run on
    /// the reduced cluster unchanged.
    pub fn enter_group(&mut self, members: &[u32]) {
        debug_assert!(members.windows(2).all(|w| w[0] < w[1]), "group must be sorted");
        let idx = members
            .iter()
            .position(|&r| r == self.world_rank)
            .expect("enter_group: this rank is not a member");
        self.rank = idx as u32;
        self.n = members.len();
        self.group = Some(members.to_vec());
    }

    /// Restore full-world addressing after [`Comm::enter_group`].
    pub fn leave_group(&mut self) {
        self.group = None;
        self.rank = self.world_rank;
        self.n = self.world_n;
    }

    /// Translate a rank in the current addressing space to a world
    /// rank.
    fn to_world(&self, r: u32) -> u32 {
        match &self.group {
            Some(g) => g[r as usize],
            None => r,
        }
    }

    /// Translate a world rank to the current addressing space; `None`
    /// if the sender is outside the active group.
    fn from_world(&self, w: u32) -> Option<u32> {
        match &self.group {
            Some(g) => g.binary_search(&w).ok().map(|i| i as u32),
            None => Some(w),
        }
    }

    pub fn send(&self, to: u32, tag: u32, data: Vec<u8>) {
        // sender-side accounting (a partitioned link still pays to send)
        crate::obs::registry::record_send(tag, data.len());
        let to_world = self.to_world(to);
        if let Some(plan) = &self.plan {
            if plan.cut(self.world_rank, to_world, self.fault_clock) {
                return; // partitioned link: the message is lost
            }
        }
        // a dropped peer ends the protocol; ignore send failures then
        let _ = self.senders[to_world as usize].send(Msg {
            from: self.world_rank,
            tag,
            epoch: self.epoch,
            data,
        });
    }

    /// Blocking receive with timeout. [`RecvError::Disconnected`] means
    /// every sender endpoint (including this node's own loopback) has
    /// been dropped — the cluster is gone, not merely slow. This is the
    /// raw primitive: no epoch filtering, no pending buffer, world
    /// `from`.
    pub fn recv(&self, timeout: Duration) -> Result<Msg, RecvError> {
        match self.inbox.recv_timeout(timeout) {
            Ok(m) => {
                // arrival-side accounting: every message passes through
                // here exactly once, before parking or stale-dropping
                crate::obs::registry::record_recv(m.tag, m.data.len());
                Ok(m)
            }
            Err(RecvTimeoutError::Timeout) => Err(RecvError::Timeout),
            Err(RecvTimeoutError::Disconnected) => Err(RecvError::Disconnected),
        }
    }

    /// Receive exactly `count` messages (or fewer on timeout /
    /// disconnect). Messages parked by [`Comm::recv_tagged`] are not
    /// consulted — this is the raw in-arrival-order primitive.
    pub fn recv_n(&self, count: usize, timeout: Duration) -> Vec<Msg> {
        let mut out = Vec::with_capacity(count);
        for _ in 0..count {
            match self.recv(timeout) {
                Ok(m) => out.push(m),
                Err(_) => break,
            }
        }
        out
    }

    /// Whether a buffered/arriving message satisfies a `recv_tagged`
    /// for `tag` at the current epoch.
    fn matches(&self, m: &Msg, tag: u32) -> bool {
        m.tag == tag
            && (is_ctrl_tag(tag) || m.epoch == self.epoch)
            && (is_ctrl_tag(tag) || self.from_world(m.from).is_some())
    }

    /// Whether a message belongs to a dead epoch and must be dropped
    /// (never delivered, never parked). Control traffic is exempt.
    fn is_stale(&self, m: &Msg) -> bool {
        !is_ctrl_tag(m.tag) && m.epoch < self.epoch
    }

    /// Group-translate a matched message for delivery.
    fn deliver(&self, mut m: Msg) -> Msg {
        if !is_ctrl_tag(m.tag) {
            if let Some(r) = self.from_world(m.from) {
                m.from = r;
            }
        }
        m
    }

    /// Receive exactly `count` messages carrying `tag` at the current
    /// epoch, parking out-of-phase messages in the pending buffer for a
    /// later `recv_tagged` (a fast peer may already be sending the next
    /// phase while we drain this one). Messages from dead epochs are
    /// dropped and counted ([`Comm::stale_drops`]), never matched.
    ///
    /// `Ok` guarantees the full count; [`CommError::Timeout`] /
    /// [`CommError::Disconnected`] carry the partial delivery so the
    /// caller can tell who went missing. Control-namespace tags match
    /// regardless of epoch (and keep world `from` fields).
    pub fn recv_tagged(
        &mut self,
        tag: u32,
        count: usize,
        timeout: Duration,
    ) -> Result<Vec<Msg>, CommError> {
        let mut out = Vec::with_capacity(count);
        let mut i = 0;
        while i < self.pending.len() {
            if self.is_stale(&self.pending[i]) {
                self.pending.remove(i);
                self.count_stale(1);
            } else if self.matches(&self.pending[i], tag) && out.len() < count {
                let m = self.pending.remove(i);
                out.push(self.deliver(m));
            } else {
                i += 1;
            }
        }
        // difflb-lint: allow(wall-clock): recv deadlines bound real waiting; virtual time is untouched
        let deadline = Instant::now() + timeout;
        while out.len() < count {
            let left = deadline.saturating_duration_since(Instant::now()); // difflb-lint: allow(wall-clock): same deadline
            match self.recv(left) {
                Ok(m) if self.is_stale(&m) => self.count_stale(1),
                Ok(m) if self.matches(&m, tag) => out.push(self.deliver(m)),
                Ok(m) => self.park(m),
                Err(RecvError::Timeout) => {
                    return Err(CommError::Timeout { tag, want: count, got: out })
                }
                Err(RecvError::Disconnected) => {
                    return Err(CommError::Disconnected { tag, want: count, got: out })
                }
            }
        }
        Ok(out)
    }

    /// Blocking receive of the next control-namespace message (pending
    /// buffer first, then the inbox). Non-control traffic encountered
    /// on the way is parked (or dropped if stale); delivered control
    /// messages keep their world `from`.
    pub fn recv_ctrl(&mut self, timeout: Duration) -> Result<Msg, RecvError> {
        if let Some(i) = self.pending.iter().position(|m| is_ctrl_tag(m.tag)) {
            return Ok(self.pending.remove(i));
        }
        // difflb-lint: allow(wall-clock): recv deadlines bound real waiting; virtual time is untouched
        let deadline = Instant::now() + timeout;
        loop {
            let left = deadline.saturating_duration_since(Instant::now()); // difflb-lint: allow(wall-clock): same deadline
            match self.recv(left) {
                Ok(m) if is_ctrl_tag(m.tag) => return Ok(m),
                Ok(m) if self.is_stale(&m) => self.count_stale(1),
                Ok(m) => self.park(m),
                Err(e) => return Err(e),
            }
        }
    }

    /// Drain every already-arrived control message (pending buffer +
    /// non-blocking inbox sweep) without waiting. Used by ranks
    /// catching up on epoch declarations they slept through.
    pub fn drain_ctrl(&mut self) -> Vec<Msg> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.pending.len() {
            if is_ctrl_tag(self.pending[i].tag) {
                out.push(self.pending.remove(i));
            } else {
                i += 1;
            }
        }
        loop {
            match self.inbox.try_recv() {
                Ok(m) => {
                    crate::obs::registry::record_recv(m.tag, m.data.len());
                    if is_ctrl_tag(m.tag) {
                        out.push(m);
                    } else if self.is_stale(&m) {
                        self.count_stale(1);
                    } else {
                        self.park(m);
                    }
                }
                Err(TryRecvError::Empty | TryRecvError::Disconnected) => break,
            }
        }
        out
    }

    /// All-to-all barrier: returns `Ok` once every rank in the current
    /// addressing space has entered a `barrier` call with the same
    /// `tag`, or a [`BarrierError`] naming the missing ranks on
    /// timeout/teardown.
    ///
    /// Contract: the tag must be unique per logical barrier within an
    /// epoch — reusing one across two consecutive barriers lets a fast
    /// rank's second announcement satisfy a slow rank's first wait. A
    /// debug-build assertion enforces (and documents) this; release
    /// builds skip the bookkeeping.
    pub fn barrier(&mut self, tag: u32) -> Result<(), BarrierError> {
        debug_assert!(
            self.barrier_tags.insert((u64::from(self.epoch) << 32) | u64::from(tag)),
            "simnode {}: barrier tag {tag:#x} reused within epoch {} — each logical \
             barrier needs a fresh tag",
            self.rank,
            self.epoch
        );
        for p in 0..self.n as u32 {
            if p != self.rank {
                self.send(p, tag, Vec::new());
            }
        }
        match self.recv_tagged(tag, self.n - 1, self.patience) {
            Ok(_) => Ok(()),
            Err(e) => {
                self.barrier_timeouts += 1;
                crate::obs::counter!("comm.barrier_timeouts").inc();
                let arrived = e.arrived();
                let missing = (0..self.n as u32)
                    .filter(|&p| p != self.rank && !arrived.contains(&p))
                    .collect();
                Err(BarrierError { tag, missing })
            }
        }
    }
}

/// A set of simulated nodes executing a closure per rank on real
/// threads.
pub struct Cluster;

impl Cluster {
    /// Run `f(rank, comm)` on `n` threads; returns the per-rank results
    /// in rank order. Panics in workers propagate.
    pub fn run<T, F>(n: usize, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(u32, Comm) -> T + Send + Sync + Clone + 'static,
    {
        Self::run_inner(n, None, f)
    }

    /// [`Cluster::run`] with a chaos schedule installed on every
    /// endpoint (partition cuts apply inside `send`; kill/hang/delay
    /// events are executed by the distributed driver's pipeline).
    pub fn run_with_plan<T, F>(n: usize, plan: Arc<FaultPlan>, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(u32, Comm) -> T + Send + Sync + Clone + 'static,
    {
        Self::run_inner(n, Some(plan), f)
    }

    fn run_inner<T, F>(n: usize, plan: Option<Arc<FaultPlan>>, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(u32, Comm) -> T + Send + Sync + Clone + 'static,
    {
        let mut senders = Vec::with_capacity(n);
        let mut inboxes = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel::<Msg>();
            senders.push(tx);
            inboxes.push(rx);
        }
        let mut handles = Vec::with_capacity(n);
        for (rank, inbox) in inboxes.into_iter().enumerate() {
            let mut comm = Comm::new(rank as u32, n, senders.clone(), inbox);
            comm.plan.clone_from(&plan);
            let f = f.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("simnode-{rank}"))
                    .spawn(move || {
                        // rank context: log lines and trace events from
                        // this thread are attributed to the simnet rank
                        crate::obs::set_rank(Some(rank as u32));
                        let out = f(rank as u32, comm);
                        // any span this node buffered and did not ship
                        // to rank 0 survives into the process sink
                        crate::obs::trace::flush_local();
                        out
                    })
                    .expect("spawn simnode"),
            );
        }
        drop(senders);
        handles.into_iter().map(|h| h.join().expect("simnode panicked")).collect()
    }
}

/// α–β network model with intra-node discount.
#[derive(Debug, Clone, Copy)]
pub struct NetModel {
    /// Per-message latency (seconds).
    pub alpha: f64,
    /// Per-byte cost (seconds/byte) across nodes.
    pub beta: f64,
    /// Intra-node traffic costs `intra_factor` × the inter-node beta
    /// (shared-memory transfer), with no alpha.
    pub intra_factor: f64,
}

impl Default for NetModel {
    fn default() -> Self {
        // ~2µs latency, ~25 GB/s effective inter-node bandwidth,
        // intra-node ~10x cheaper: Slingshot-ish numbers for a
        // Perlmutter-flavored simulation.
        NetModel { alpha: 2e-6, beta: 1.0 / 25e9, intra_factor: 0.1 }
    }
}

impl NetModel {
    pub fn inter_time(&self, msgs: u64, bytes: f64) -> f64 {
        self.alpha * msgs as f64 + self.beta * bytes
    }

    pub fn intra_time(&self, bytes: f64) -> f64 {
        self.beta * self.intra_factor * bytes
    }
}

/// Accumulates per-node traffic for one app iteration and converts it
/// to per-node communication time under a [`NetModel`].
#[derive(Debug, Clone)]
pub struct CostTracker {
    pub n_nodes: usize,
    pub inter_msgs: Vec<u64>,
    pub inter_bytes: Vec<f64>,
    pub intra_bytes: Vec<f64>,
}

impl CostTracker {
    pub fn new(n_nodes: usize) -> CostTracker {
        CostTracker {
            n_nodes,
            inter_msgs: vec![0; n_nodes],
            inter_bytes: vec![0.0; n_nodes],
            intra_bytes: vec![0.0; n_nodes],
        }
    }

    /// Record `bytes` moving from `from` to `to` (node indices); both
    /// endpoints pay (send + receive overlap is not modeled).
    pub fn record(&mut self, from: u32, to: u32, bytes: f64) {
        if from == to {
            self.intra_bytes[from as usize] += bytes;
        } else {
            self.inter_msgs[from as usize] += 1;
            self.inter_msgs[to as usize] += 1;
            self.inter_bytes[from as usize] += bytes;
            self.inter_bytes[to as usize] += bytes;
        }
    }

    /// Per-node communication seconds under `model`.
    pub fn comm_times(&self, model: &NetModel) -> Vec<f64> {
        (0..self.n_nodes)
            .map(|i| {
                model.inter_time(self.inter_msgs[i], self.inter_bytes[i])
                    + model.intra_time(self.intra_bytes[i])
            })
            .collect()
    }

    pub fn reset(&mut self) {
        self.inter_msgs.iter_mut().for_each(|x| *x = 0);
        self.inter_bytes.iter_mut().for_each(|x| *x = 0.0);
        self.intra_bytes.iter_mut().for_each(|x| *x = 0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cluster_all_to_all_exchange() {
        let results = Cluster::run(4, |rank, comm| {
            for to in 0..4u32 {
                if to != rank {
                    comm.send(to, 7, vec![rank as u8]);
                }
            }
            let msgs = comm.recv_n(3, Duration::from_secs(5));
            let mut froms: Vec<u32> = msgs.iter().map(|m| m.from).collect();
            froms.sort_unstable();
            froms
        });
        for (rank, froms) in results.iter().enumerate() {
            let expect: Vec<u32> = (0..4u32).filter(|&r| r as usize != rank).collect();
            assert_eq!(froms, &expect);
        }
    }

    #[test]
    fn recv_timeout_is_distinct_from_disconnect() {
        // Live cluster, no traffic: plain Timeout (never Disconnected —
        // each node's own loopback sender keeps its inbox alive).
        let r = Cluster::run(2, |_rank, comm| comm.recv(Duration::from_millis(10)));
        assert_eq!(r, vec![Err(RecvError::Timeout), Err(RecvError::Timeout)]);
    }

    #[test]
    fn recv_reports_dead_peers_immediately() {
        // Hand-built endpoint whose every sender has been dropped: the
        // receive must fail fast with Disconnected, not burn a timeout.
        let (tx, rx) = channel::<Msg>();
        drop(tx);
        let dead = Comm::new(1, 2, Vec::new(), rx);
        let t = std::time::Instant::now();
        assert_eq!(dead.recv(Duration::from_secs(30)), Err(RecvError::Disconnected));
        assert!(t.elapsed() < Duration::from_secs(5), "burned the timeout");
    }

    #[test]
    fn recv_tagged_reports_dead_cluster() {
        let (tx, rx) = channel::<Msg>();
        drop(tx);
        let mut dead = Comm::new(0, 2, Vec::new(), rx);
        let t = std::time::Instant::now();
        match dead.recv_tagged(0x42, 1, Duration::from_secs(30)) {
            Err(CommError::Disconnected { tag: 0x42, want: 1, got }) => {
                assert!(got.is_empty())
            }
            other => panic!("expected Disconnected, got {other:?}"),
        }
        assert!(t.elapsed() < Duration::from_secs(5), "burned the timeout");
    }

    #[test]
    fn recv_tagged_timeout_carries_partial_delivery() {
        let r = Cluster::run(3, |rank, mut comm| {
            if rank == 1 {
                comm.send(0, 9, vec![1]);
            }
            if rank == 0 {
                // expect two messages of tag 9, only rank 1 sends
                match comm.recv_tagged(9, 2, Duration::from_millis(100)) {
                    Err(CommError::Timeout { tag: 9, want: 2, got }) => {
                        got.iter().map(|m| m.from).collect()
                    }
                    other => panic!("expected Timeout, got {other:?}"),
                }
            } else {
                Vec::new()
            }
        });
        assert_eq!(r[0], vec![1]);
    }

    #[test]
    fn net_model_costs() {
        let m = NetModel { alpha: 1e-6, beta: 1e-9, intra_factor: 0.1 };
        assert!((m.inter_time(10, 1e6) - (1e-5 + 1e-3)).abs() < 1e-12);
        assert!((m.intra_time(1e6) - 1e-4).abs() < 1e-12);
    }

    #[test]
    fn tracker_attributes_both_endpoints() {
        let mut t = CostTracker::new(3);
        t.record(0, 1, 100.0);
        t.record(2, 2, 50.0);
        assert_eq!(t.inter_msgs, vec![1, 1, 0]);
        assert_eq!(t.inter_bytes, vec![100.0, 100.0, 0.0]);
        assert_eq!(t.intra_bytes, vec![0.0, 0.0, 50.0]);
        let times = t.comm_times(&NetModel::default());
        assert!(times[0] > 0.0 && times[0] == times[1] && times[2] > 0.0);
        t.reset();
        assert_eq!(t.inter_bytes, vec![0.0; 3]);
    }

    #[test]
    fn recv_tagged_buffers_out_of_phase() {
        let results = Cluster::run(2, |rank, mut comm| {
            let peer = 1 - rank;
            // send three phases out of order
            comm.send(peer, 3, vec![30]);
            comm.send(peer, 1, vec![10]);
            comm.send(peer, 2, vec![20]);
            // drain in canonical phase order
            let a = comm.recv_tagged(1, 1, Duration::from_secs(5)).expect("phase 1");
            let b = comm.recv_tagged(2, 1, Duration::from_secs(5)).expect("phase 2");
            let c = comm.recv_tagged(3, 1, Duration::from_secs(5)).expect("phase 3");
            (a[0].data.clone(), b[0].data.clone(), c[0].data.clone())
        });
        for r in results {
            assert_eq!(r, (vec![10], vec![20], vec![30]));
        }
    }

    #[test]
    fn barrier_holds_until_all_arrive() {
        // Every rank announces "pre" to rank 0 before entering the
        // barrier; once rank 0's barrier completes, all announcements
        // must already be in flight — observable with a tiny timeout.
        let results = Cluster::run(4, |rank, mut comm| {
            comm.send(0, 0x50, vec![rank as u8]);
            if rank == 2 {
                std::thread::sleep(Duration::from_millis(50)); // straggler
            }
            comm.barrier(0x60).expect("barrier");
            if rank == 0 {
                let pre =
                    comm.recv_tagged(0x50, 4, Duration::from_secs(5)).expect("announcements");
                pre.len()
            } else {
                0
            }
        });
        assert_eq!(results[0], 4);
    }

    #[test]
    fn barrier_timeout_names_missing_ranks() {
        let results = Cluster::run(3, |rank, mut comm| {
            if rank == 2 {
                // rank 2 never enters the barrier; keep the thread
                // alive long enough that peers see silence, not a
                // teardown race
                std::thread::sleep(Duration::from_millis(300));
                return None;
            }
            comm.set_patience(Duration::from_millis(100));
            Some(comm.barrier(0x70))
        });
        for r in &results[..2] {
            assert_eq!(
                r.clone().unwrap(),
                Err(BarrierError { tag: 0x70, missing: vec![2] })
            );
        }
    }

    #[test]
    fn stale_epoch_messages_are_dropped_and_counted() {
        let results = Cluster::run(2, |rank, mut comm| {
            if rank == 0 {
                comm.send(1, 5, vec![1]); // epoch-0 payload
                comm.send(1, CTRL_NS | 1, vec![]); // ordered marker
                return (0, 0);
            }
            // park the epoch-0 payload while waiting for the marker
            let m = comm.recv_ctrl(Duration::from_secs(5)).expect("marker");
            assert_eq!(m.tag, CTRL_NS | 1);
            // epoch change: the parked payload is now stale
            let dropped = comm.set_epoch(1);
            let after = comm.recv_tagged(5, 1, Duration::from_millis(50));
            assert!(
                matches!(after, Err(CommError::Timeout { ref got, .. }) if got.is_empty()),
                "stale message was delivered: {after:?}"
            );
            (dropped, comm.stale_drops())
        });
        assert_eq!(results[1], (1, 1));
    }

    #[test]
    fn group_mode_translates_ranks() {
        let members = vec![0u32, 2, 3];
        let results = Cluster::run(4, move |rank, mut comm| {
            if rank == 1 {
                // outside the group: idle but alive
                std::thread::sleep(Duration::from_millis(100));
                return Vec::new();
            }
            comm.enter_group(&members);
            let me = comm.rank; // dense group index
            let n = comm.n;
            assert_eq!(n, 3);
            for p in 0..n as u32 {
                if p != me {
                    comm.send(p, 11, vec![me as u8]);
                }
            }
            let msgs = comm.recv_tagged(11, n - 1, Duration::from_secs(5)).expect("group");
            comm.leave_group();
            assert_eq!(comm.rank, rank);
            let mut froms: Vec<u32> = msgs.iter().map(|m| m.from).collect();
            froms.sort_unstable();
            froms
        });
        // delivered `from` fields are dense group indices
        assert_eq!(results[0], vec![1, 2]); // world 2→1, 3→2
        assert_eq!(results[2], vec![0, 2]);
        assert_eq!(results[3], vec![0, 1]);
    }

    #[test]
    fn partition_cut_drops_messages() {
        let plan = Arc::new(FaultPlan::parse("part:1@0").expect("plan"));
        let results = Cluster::run_with_plan(3, plan, |rank, mut comm| {
            comm.set_fault_round(0);
            if rank == 0 {
                comm.send(1, 7, vec![10]); // cut
                comm.send(2, 7, vec![20]); // delivered
                return 0;
            }
            match comm.recv_tagged(7, 1, Duration::from_millis(150)) {
                Ok(msgs) => i32::from(msgs[0].data[0]),
                Err(CommError::Timeout { .. }) => -1,
                Err(e) => panic!("{e}"),
            }
        });
        assert_eq!(results[1], -1, "message across the cut must be lost");
        assert_eq!(results[2], 20);
    }
}
