//! Threaded message-passing cluster + α–β communication cost model.
//!
//! [`Cluster::run`] spawns one OS thread per simulated node and hands
//! each a [`Comm`] endpoint (send/recv/barrier over std mpsc channels) —
//! enough to execute genuinely distributed protocols (the stage-1
//! handshake in [`super::protocol`]) without any external runtime.
//!
//! [`NetModel`] converts message/byte counts into seconds the way the
//! strong-scaling analysis needs: `t = α·msgs + β·bytes`, with
//! intra-node traffic discounted (shared memory vs NIC).

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::Duration;

/// A message between simulated nodes: (source, tag, payload).
#[derive(Debug, Clone, PartialEq)]
pub struct Msg {
    pub from: u32,
    pub tag: u32,
    pub data: Vec<u8>,
}

/// Per-node communication endpoint.
pub struct Comm {
    pub rank: u32,
    pub n: usize,
    senders: Vec<Sender<Msg>>,
    inbox: Receiver<Msg>,
}

impl Comm {
    pub fn send(&self, to: u32, tag: u32, data: Vec<u8>) {
        // a dropped peer ends the protocol; ignore send failures then
        let _ = self.senders[to as usize].send(Msg { from: self.rank, tag, data });
    }

    /// Blocking receive with timeout (None on timeout).
    pub fn recv(&self, timeout: Duration) -> Option<Msg> {
        match self.inbox.recv_timeout(timeout) {
            Ok(m) => Some(m),
            Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => None,
        }
    }

    /// Receive exactly `count` messages (or fewer on timeout).
    pub fn recv_n(&self, count: usize, timeout: Duration) -> Vec<Msg> {
        let mut out = Vec::with_capacity(count);
        for _ in 0..count {
            match self.recv(timeout) {
                Some(m) => out.push(m),
                None => break,
            }
        }
        out
    }
}

/// A set of simulated nodes executing a closure per rank on real
/// threads.
pub struct Cluster;

impl Cluster {
    /// Run `f(rank, comm)` on `n` threads; returns the per-rank results
    /// in rank order. Panics in workers propagate.
    pub fn run<T, F>(n: usize, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(u32, Comm) -> T + Send + Sync + Clone + 'static,
    {
        let mut senders = Vec::with_capacity(n);
        let mut inboxes = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel::<Msg>();
            senders.push(tx);
            inboxes.push(rx);
        }
        let mut handles = Vec::with_capacity(n);
        for (rank, inbox) in inboxes.into_iter().enumerate() {
            let comm = Comm { rank: rank as u32, n, senders: senders.clone(), inbox };
            let f = f.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("simnode-{rank}"))
                    .spawn(move || f(rank as u32, comm))
                    .expect("spawn simnode"),
            );
        }
        drop(senders);
        handles.into_iter().map(|h| h.join().expect("simnode panicked")).collect()
    }
}

/// α–β network model with intra-node discount.
#[derive(Debug, Clone, Copy)]
pub struct NetModel {
    /// Per-message latency (seconds).
    pub alpha: f64,
    /// Per-byte cost (seconds/byte) across nodes.
    pub beta: f64,
    /// Intra-node traffic costs `intra_factor` × the inter-node beta
    /// (shared-memory transfer), with no alpha.
    pub intra_factor: f64,
}

impl Default for NetModel {
    fn default() -> Self {
        // ~2µs latency, ~25 GB/s effective inter-node bandwidth,
        // intra-node ~10x cheaper: Slingshot-ish numbers for a
        // Perlmutter-flavored simulation.
        NetModel { alpha: 2e-6, beta: 1.0 / 25e9, intra_factor: 0.1 }
    }
}

impl NetModel {
    pub fn inter_time(&self, msgs: u64, bytes: f64) -> f64 {
        self.alpha * msgs as f64 + self.beta * bytes
    }

    pub fn intra_time(&self, bytes: f64) -> f64 {
        self.beta * self.intra_factor * bytes
    }
}

/// Accumulates per-node traffic for one app iteration and converts it
/// to per-node communication time under a [`NetModel`].
#[derive(Debug, Clone)]
pub struct CostTracker {
    pub n_nodes: usize,
    pub inter_msgs: Vec<u64>,
    pub inter_bytes: Vec<f64>,
    pub intra_bytes: Vec<f64>,
}

impl CostTracker {
    pub fn new(n_nodes: usize) -> CostTracker {
        CostTracker {
            n_nodes,
            inter_msgs: vec![0; n_nodes],
            inter_bytes: vec![0.0; n_nodes],
            intra_bytes: vec![0.0; n_nodes],
        }
    }

    /// Record `bytes` moving from `from` to `to` (node indices); both
    /// endpoints pay (send + receive overlap is not modeled).
    pub fn record(&mut self, from: u32, to: u32, bytes: f64) {
        if from == to {
            self.intra_bytes[from as usize] += bytes;
        } else {
            self.inter_msgs[from as usize] += 1;
            self.inter_msgs[to as usize] += 1;
            self.inter_bytes[from as usize] += bytes;
            self.inter_bytes[to as usize] += bytes;
        }
    }

    /// Per-node communication seconds under `model`.
    pub fn comm_times(&self, model: &NetModel) -> Vec<f64> {
        (0..self.n_nodes)
            .map(|i| {
                model.inter_time(self.inter_msgs[i], self.inter_bytes[i])
                    + model.intra_time(self.intra_bytes[i])
            })
            .collect()
    }

    pub fn reset(&mut self) {
        self.inter_msgs.iter_mut().for_each(|x| *x = 0);
        self.inter_bytes.iter_mut().for_each(|x| *x = 0.0);
        self.intra_bytes.iter_mut().for_each(|x| *x = 0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cluster_all_to_all_exchange() {
        let results = Cluster::run(4, |rank, comm| {
            for to in 0..4u32 {
                if to != rank {
                    comm.send(to, 7, vec![rank as u8]);
                }
            }
            let msgs = comm.recv_n(3, Duration::from_secs(5));
            let mut froms: Vec<u32> = msgs.iter().map(|m| m.from).collect();
            froms.sort_unstable();
            froms
        });
        for (rank, froms) in results.iter().enumerate() {
            let expect: Vec<u32> = (0..4u32).filter(|&r| r as usize != rank).collect();
            assert_eq!(froms, &expect);
        }
    }

    #[test]
    fn recv_timeout_returns_none() {
        let r = Cluster::run(2, |_rank, comm| comm.recv(Duration::from_millis(10)).is_none());
        assert_eq!(r, vec![true, true]);
    }

    #[test]
    fn net_model_costs() {
        let m = NetModel { alpha: 1e-6, beta: 1e-9, intra_factor: 0.1 };
        assert!((m.inter_time(10, 1e6) - (1e-5 + 1e-3)).abs() < 1e-12);
        assert!((m.intra_time(1e6) - 1e-4).abs() < 1e-12);
    }

    #[test]
    fn tracker_attributes_both_endpoints() {
        let mut t = CostTracker::new(3);
        t.record(0, 1, 100.0);
        t.record(2, 2, 50.0);
        assert_eq!(t.inter_msgs, vec![1, 1, 0]);
        assert_eq!(t.inter_bytes, vec![100.0, 100.0, 0.0]);
        assert_eq!(t.intra_bytes, vec![0.0, 0.0, 50.0]);
        let times = t.comm_times(&NetModel::default());
        assert!(times[0] > 0.0 && times[0] == times[1] && times[2] > 0.0);
        t.reset();
        assert_eq!(t.inter_bytes, vec![0.0; 3]);
    }
}
