//! The stage-1 neighbor handshake executed as a **real distributed
//! protocol** over the threaded [`Cluster`](super::Cluster) — the same
//! state machine as `strategies::diffusion::neighbor::select_neighbors`,
//! but with every decision made locally per node and every interaction a
//! real message. Integration tests assert the two produce identical
//! pairings, validating that the round-synchronous sequential
//! implementation used inside the strategies is a faithful model of the
//! distributed execution (the paper's strategy runs inside Charm++ this
//! way). [`handshake_node`] is the per-node body; `crate::distributed`
//! runs it inline in its full-pipeline node threads, followed by the
//! stage-2/stage-3 protocols, on the same [`Comm`] endpoints.
//!
//! Wire protocol per round (tags, offset by the caller's `tag_base`):
//!   0 REQ   — one per peer: `[1]` requesting / `[0]` not
//!   1 RESP  — to each requester: `[1]` accept / `[0]` reject
//!   2 ACK   — to each accepting responder: `[1]` confirm / `[0]` cancel
//!   3 DONE  — satisfaction bit for global termination

use super::network::{Cluster, Comm, CommError};
use crate::strategies::diffusion::neighbor::{Candidates, NeighborGraph};

/// Run the distributed handshake on `n` threads; returns the symmetric
/// neighbor graph (same contract as the sequential implementation).
pub fn distributed_select_neighbors(
    candidates: &Candidates,
    k: usize,
    max_rounds: usize,
) -> NeighborGraph {
    let n = candidates.len();
    if n == 0 {
        return NeighborGraph { adj: vec![] };
    }
    let cands = std::sync::Arc::new(candidates.clone());
    let adj = Cluster::run(n, move |rank, mut comm| {
        handshake_node(&mut comm, &cands[rank as usize], k, max_rounds, 0)
            .expect("handshake protocol failed on a healthy cluster")
    });
    NeighborGraph { adj }
}

/// One node's handshake: runs the paper's stage-1 state machine over
/// real messages and returns this node's confirmed neighbor set
/// (sorted). `tag_base` namespaces the wire tags so callers embedding
/// the handshake in a longer protocol (the distributed LB pipeline)
/// can keep phases disjoint; it must leave the low 24 bits clear
/// (rounds use bits 8..24, phases bits 0..8). A peer failing
/// mid-handshake surfaces as `Err` — the caller (the epoch/restart
/// layer) decides whether that means recovery or abort.
pub fn handshake_node(
    comm: &mut Comm,
    my_cands: &[u32],
    k: usize,
    max_rounds: usize,
    tag_base: u32,
) -> Result<Vec<u32>, CommError> {
    debug_assert_eq!(tag_base & 0x00FF_FFFF, 0, "tag_base clobbers round/phase bits");
    // rounds occupy tag bits 8..24; overflowing them would collide with
    // the caller's other protocol namespaces (same bound as stage 2).
    assert!(max_rounds < (1 << 16), "handshake_max_rounds exceeds the round tag space");
    let rank = comm.rank;
    let n = comm.n;
    let peers: Vec<u32> = (0..n as u32).filter(|&p| p != rank).collect();
    let mut confirmed: Vec<u32> = Vec::new();
    let mut holds: usize = 0;
    let mut cursor = 0usize;
    let mut wrapped = false;

    for round in 0..max_rounds as u32 {
        let tag = |phase: u32| tag_base | (round << 8) | phase;

        // ---- Phase A: decide + send requests (batch: one msg per peer).
        let l = k.saturating_sub(confirmed.len());
        let want = if l == 0 {
            0
        } else if l / 2 == 0 && !confirmed.is_empty() {
            1 // stall relief, see sequential impl
        } else {
            l / 2
        };
        let dbg = std::env::var("DIFFLB_PROTO_DBG").is_ok();
        let mut requested: Vec<u32> = Vec::new();
        while requested.len() < want {
            if cursor >= my_cands.len() {
                if wrapped || my_cands.is_empty() {
                    break;
                }
                wrapped = true;
                cursor = 0;
                continue;
            }
            let c = my_cands[cursor];
            cursor += 1;
            if !confirmed.contains(&c) && !requested.contains(&c) {
                requested.push(c);
            }
        }
        if dbg {
            eprintln!("r{round} n{rank}: confirmed={confirmed:?} holds={holds} want={want} requested={requested:?}");
        }
        for &p in &peers {
            comm.send(p, tag(0), vec![u8::from(requested.contains(&p))]);
        }

        // ---- Phase B: collect requests, respond (sorted by requester).
        let mut reqs: Vec<u32> = comm
            .recv_tagged(tag(0), peers.len(), comm.patience())?
            .into_iter()
            .filter(|m| m.data == [1])
            .map(|m| m.from)
            .collect();
        reqs.sort_unstable();
        if dbg { eprintln!("r{round} n{rank}: reqs_in={reqs:?}"); }
        let mut accepted_from: Vec<u32> = Vec::new();
        for &from in &reqs {
            let full = confirmed.len() >= k || confirmed.len() + holds >= k;
            let accept = !full && !confirmed.contains(&from);
            if accept {
                holds += 1;
                accepted_from.push(from);
            }
            comm.send(from, tag(1), vec![u8::from(accept)]);
        }

        // ---- Phase C: collect responses to our requests, ack/cancel.
        let mut accepts: Vec<u32> = comm
            .recv_tagged(tag(1), requested.len(), comm.patience())?
            .into_iter()
            .filter(|m| m.data == [1])
            .map(|m| m.from)
            .collect();
        accepts.sort_unstable();
        if dbg { eprintln!("r{round} n{rank}: accepts_in={accepts:?}"); }
        for &resp in &accepts {
            // a hold issued to resp itself is this same prospective
            // pairing and does not count against capacity (see the
            // sequential implementation)
            let same_pair = usize::from(accepted_from.contains(&resp));
            let can_confirm =
                confirmed.len() + holds - same_pair < k && !confirmed.contains(&resp);
            if can_confirm {
                confirmed.push(resp);
            }
            comm.send(resp, tag(2), vec![u8::from(can_confirm)]);
        }

        // ---- Process acks for the accepts we issued (sorted by sender
        // for determinism; arrival order is scheduling-dependent).
        let mut acks = comm.recv_tagged(tag(2), accepted_from.len(), comm.patience())?;
        acks.sort_by_key(|m| m.from);
        for m in acks {
            holds -= 1;
            if m.data == [1] && !confirmed.contains(&m.from) && confirmed.len() < k {
                confirmed.push(m.from);
            }
        }

        // ---- Global termination: everyone satisfied?
        let satisfied = confirmed.len() >= k || (wrapped && cursor >= my_cands.len());
        for &p in &peers {
            comm.send(p, tag(3), vec![u8::from(satisfied)]);
        }
        let done_msgs = comm.recv_tagged(tag(3), peers.len(), comm.patience())?;
        if satisfied && done_msgs.iter().all(|m| m.data == [1]) {
            break;
        }
    }
    confirmed.sort_unstable();
    Ok(confirmed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::strategies::diffusion::neighbor::select_neighbors;

    fn ring_candidates(n: usize) -> Candidates {
        (0..n)
            .map(|i| {
                let mut peers: Vec<(u32, usize)> = (0..n)
                    .filter(|&j| j != i)
                    .map(|j| {
                        let d = (i as isize - j as isize).unsigned_abs();
                        (j as u32, d.min(n - d))
                    })
                    .collect();
                peers.sort_by_key(|&(j, d)| (d, j));
                peers.into_iter().map(|(j, _)| j).collect()
            })
            .collect()
    }

    #[test]
    fn distributed_matches_sequential_on_ring() {
        for k in [2usize, 4] {
            let cands = ring_candidates(8);
            let seq = select_neighbors(&cands, k, 16);
            let dist = distributed_select_neighbors(&cands, k, 16);
            assert_eq!(seq.adj, dist.adj, "k={k}");
        }
    }

    #[test]
    fn distributed_is_symmetric_and_bounded() {
        let cands = ring_candidates(12);
        let g = distributed_select_neighbors(&cands, 3, 16);
        assert!(g.is_symmetric());
        assert!(g.max_degree() <= 3);
    }

    #[test]
    fn single_node_cluster() {
        let g = distributed_select_neighbors(&vec![vec![]], 4, 4);
        assert_eq!(g.adj, vec![Vec::<u32>::new()]);
    }

    #[test]
    fn tag_base_does_not_change_pairings() {
        let cands = ring_candidates(6);
        let base = distributed_select_neighbors(&cands, 2, 16);
        let shifted = {
            let c = std::sync::Arc::new(cands);
            let adj = Cluster::run(6, move |rank, mut comm| {
                handshake_node(&mut comm, &c[rank as usize], 2, 16, 0x0700_0000)
                    .expect("handshake")
            });
            NeighborGraph { adj }
        };
        assert_eq!(base.adj, shifted.adj);
    }
}
