//! Distributed-execution substrate: a threaded message-passing cluster
//! (stand-in for Charm++/UCX process messaging) and an α–β network cost
//! model used to account simulated communication time at scale
//! (DESIGN.md substitution table — Perlmutter runs are reproduced as
//! modeled time over real computation).

pub mod network;
pub mod protocol;

pub use network::{Cluster, Comm, CostTracker, Msg, NetModel, RecvError};
