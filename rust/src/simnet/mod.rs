//! Distributed-execution substrate: a threaded message-passing cluster
//! (stand-in for Charm++/UCX process messaging), a fault-injection
//! plane for chaos testing the runtime against node death and
//! partitions, and an α–β network cost model used to account simulated
//! communication time at scale (DESIGN.md substitution table —
//! Perlmutter runs are reproduced as modeled time over real
//! computation).

pub mod fault;
pub mod network;
pub mod protocol;

pub use fault::{FaultEvent, FaultKind, FaultPlan, PartitionEvent, StagePoint};
pub use network::{
    is_ctrl_tag, BarrierError, Cluster, Comm, CommError, CostTracker, Msg, NetModel, RecvError,
    CTRL_NS, // difflb-lint: allow(ctrl-ns): public re-export, not a use of the namespace
};
