//! Fault-injection plane for the simulated cluster: a seed-deterministic
//! schedule of node deaths, hangs, delays, and network partitions that
//! the distributed runtime executes against — the chaos counterpart of
//! [`SpeedSchedule`](crate::model::SpeedSchedule) (speeds model degraded
//! nodes; this models absent ones).
//!
//! A [`FaultPlan`] is pure data: *what* goes wrong, *where* (rank),
//! *when* (LB round + pipeline stage). Injection happens at two layers:
//!
//! * [`Comm::send`](super::Comm::send) consults the plan's partition
//!   events (messages crossing an active cut are dropped), keyed by the
//!   fault clock the driver advances once per LB round;
//! * the distributed driver's pipeline consults [`FaultPlan::my_fault`]
//!   at stage entry — a `Kill` victim returns from its node thread
//!   (its endpoint drops; peers see silence), `Hang`/`Delay` victims
//!   sleep (`hang_ms` is sized to exceed the detection window, so a
//!   hung rank wakes up already excluded; `delay_ms` stays under it, so
//!   a delayed rank rejoins the same epoch untouched).
//!
//! An empty (inactive) plan is the default everywhere and costs
//! nothing: no checkpoint traffic, no shortened timeouts, and the
//! fault-free protocol paths are bit-identical to a build without this
//! module (`tests/chaos.rs` locks that down).
//!
//! Any rank — including rank 0 — is a valid victim: the recovery layer
//! (`distributed::epoch`) elects the lowest-alive world rank as failure
//! coordinator, so killing or partitioning away the current coordinator
//! just moves the role. Partitions may also carry a `heal_round`, after
//! which the cut lifts and the exiled minority rejoins through the
//! driver's joiner path. The only plans `validate` still rejects are
//! structural impossibilities: out-of-range ranks, round-0 cuts, heals
//! that precede their cut, and schedules that would strand the
//! survivors below quorum.

use anyhow::{bail, Result};

use crate::util::rng::Rng;

/// What happens to the victim rank at its scheduled point.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// The rank dies: its node thread returns, every endpoint drops.
    Kill,
    /// The rank stalls for [`FaultPlan::hang_ms`] — longer than the
    /// detection window, so it is excluded and must discover that on
    /// waking.
    Hang,
    /// The rank stalls for [`FaultPlan::delay_ms`] — shorter than the
    /// detection window, so the run completes unchanged.
    Delay,
}

/// Where in the LB pipeline the fault fires (mid-pipeline by
/// construction: the per-round state checkpoint has already been taken,
/// so recovery re-homes exact state).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StagePoint {
    /// Entry of the stage-1 neighbor handshake.
    Handshake,
    /// Entry of stage-2 virtual load balancing.
    VirtualLb,
    /// Entry of stage-3 object selection.
    Selection,
}

impl StagePoint {
    fn parse(s: &str) -> Result<StagePoint> {
        Ok(match s {
            "s1" => StagePoint::Handshake,
            "s2" => StagePoint::VirtualLb,
            "s3" => StagePoint::Selection,
            other => bail!("unknown stage '{other}' (expected s1, s2 or s3)"),
        })
    }
}

/// One scheduled per-rank fault.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultEvent {
    pub rank: u32,
    pub lb_round: u32,
    pub stage: StagePoint,
    pub kind: FaultKind,
}

/// A network partition starting at `lb_round`: messages between the
/// minority set and the rest are dropped from that round's pipeline
/// onward. With `heal_round: None` the cut is permanent and the
/// minority side (whichever half lacks quorum) exits dead; with
/// `Some(h)` the cut lifts when the fault clock reaches `h` and the
/// exiled minority rejoins the run through the driver's joiner path.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PartitionEvent {
    pub lb_round: u32,
    /// LB round at which the cut lifts (exclusive end of the exile:
    /// the minority participates in round `heal_round` again).
    /// `None` = permanent.
    pub heal_round: Option<u32>,
    pub minority: Vec<u32>,
}

/// The full, seed-deterministic chaos schedule for one run.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
    pub partitions: Vec<PartitionEvent>,
    /// Failure-detection patience in milliseconds: protocol receives
    /// use this instead of [`Comm::TIMEOUT`](super::Comm::TIMEOUT) when
    /// the plan is active, and the coordinator's ping window derives
    /// from it.
    pub detect_ms: u64,
    /// How long a [`FaultKind::Hang`] victim sleeps (must exceed the
    /// detection + epoch-declaration window).
    pub hang_ms: u64,
    /// How long a [`FaultKind::Delay`] victim sleeps (must stay under
    /// `detect_ms`).
    pub delay_ms: u64,
}

impl FaultPlan {
    /// The inert plan: nothing scheduled, default patience.
    pub fn none() -> FaultPlan {
        FaultPlan {
            events: Vec::new(),
            partitions: Vec::new(),
            detect_ms: 1_000,
            hang_ms: 6_000,
            delay_ms: 150,
        }
    }

    /// Whether anything is scheduled at all. Inactive plans keep every
    /// code path bit-identical to a fault-unaware build.
    pub fn is_active(&self) -> bool {
        !self.events.is_empty() || !self.partitions.is_empty()
    }

    /// Protocol patience while the plan is active.
    pub fn detect_timeout(&self) -> std::time::Duration {
        std::time::Duration::from_millis(self.detect_ms)
    }

    /// The fault scheduled for `rank` at LB round `lb_round`, if any.
    pub fn my_fault(&self, rank: u32, lb_round: u32) -> Option<&FaultEvent> {
        self.events.iter().find(|e| e.rank == rank && e.lb_round == lb_round)
    }

    /// Whether a message `a` → `b` is cut by a partition active at
    /// fault-clock `clock` (the driver advances the clock to `r` when
    /// entering LB round `r`'s pipeline).
    pub fn cut(&self, a: u32, b: u32, clock: u64) -> bool {
        self.partitions.iter().any(|p| {
            u64::from(p.lb_round) <= clock
                && p.heal_round.map_or(true, |h| clock < u64::from(h))
                && (p.minority.contains(&a) != p.minority.contains(&b))
        })
    }

    /// World ranks whose exile ends exactly at LB round `round`: the
    /// minorities of partitions healing there, minus any rank a
    /// non-Delay fault removed before the heal (a killed rank cannot
    /// rejoin; its side of the cut simply stays dead).
    pub fn healed_at(&self, round: u32) -> Vec<u32> {
        let mut out: Vec<u32> = self
            .partitions
            .iter()
            .filter(|p| p.heal_round == Some(round))
            .flat_map(|p| p.minority.iter().copied())
            .filter(|&r| {
                !self.events.iter().any(|e| {
                    e.rank == r && e.kind != FaultKind::Delay && e.lb_round < round
                })
            })
            .collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Mask of ranks that have rejoined through a heal by LB round
    /// `round` — the recovery layer's election must never hand the
    /// coordinator role to a rejoiner mid-round (the pre-heal majority
    /// holds the authoritative root state), so these ranks are barred
    /// from `epoch::elect` for the rest of the run.
    pub fn rejoined_mask(&self, n_nodes: usize, round: u32) -> Vec<bool> {
        let mut mask = vec![false; n_nodes];
        for p in &self.partitions {
            if p.heal_round.is_some_and(|h| h <= round) {
                for &r in &p.minority {
                    if (r as usize) < n_nodes {
                        mask[r as usize] = true;
                    }
                }
            }
        }
        mask
    }

    /// If `rank`'s exile starting at or before `round` eventually
    /// heals, the round at which it does; `None` when any partition
    /// containing the rank is permanent (the rank must exit dead).
    pub fn exile_until(&self, rank: u32, round: u32) -> Option<u32> {
        let mut latest: Option<u32> = None;
        for p in &self.partitions {
            if p.lb_round <= round && p.minority.contains(&rank) {
                match p.heal_round {
                    None => return None,
                    Some(h) if h > round => {
                        latest = Some(latest.map_or(h, |l: u32| l.max(h)));
                    }
                    Some(_) => {} // already healed: not this exile
                }
            }
        }
        latest
    }

    /// Sanity-check the plan against a cluster size: every rank is in
    /// range (any rank — rank 0 included — may be a victim now that the
    /// coordinator is elected), heals come strictly after their cut and
    /// never coincide with another cut's start, and no partition
    /// strands the majority side below quorum.
    pub fn validate(&self, n_nodes: usize) -> Result<()> {
        for e in &self.events {
            if e.rank as usize >= n_nodes {
                bail!("fault plan targets rank {} of {n_nodes}", e.rank);
            }
        }
        let mut victims = 0usize;
        for p in &self.partitions {
            if p.minority.is_empty() {
                bail!("partition with an empty minority");
            }
            if let Some(&bad) = p.minority.iter().find(|&&r| r as usize >= n_nodes) {
                bail!("partition references rank {bad} of {n_nodes}");
            }
            if p.lb_round == 0 {
                // the partition clock activates cuts at pipeline entry;
                // a round-0 cut would sever the bootstrap step exchange
                // before the first state checkpoint exists
                bail!("partition at round 0 (cuts must start at LB round >= 1)");
            }
            if let Some(h) = p.heal_round {
                if h <= p.lb_round {
                    bail!(
                        "partition heals at round {h} but starts at {} \
                         (heal must come strictly after the cut)",
                        p.lb_round
                    );
                }
                if self.partitions.iter().any(|q| q.lb_round == h) {
                    // the driver advances the fault clock early at a
                    // heal round so the rejoin traffic isn't cut; a
                    // partition starting at that exact round would then
                    // fire one phase too soon
                    bail!("a partition cannot start at another's heal round {h}");
                }
            }
            victims += p.minority.len();
        }
        for e in &self.events {
            if let Some(p) = self.partitions.iter().find(|p| {
                p.minority.contains(&e.rank)
                    && p.lb_round <= e.lb_round
                    && p.heal_round.map_or(true, |h| e.lb_round < h)
            }) {
                // an exiled (or permanently partitioned-away) rank runs
                // no pipeline stage, so the event could never fire
                bail!(
                    "fault targets rank {} at round {} inside its partition \
                     exile (cut at round {})",
                    e.rank,
                    e.lb_round,
                    p.lb_round
                );
            }
        }
        victims += self.events.iter().filter(|e| e.kind != FaultKind::Delay).count();
        if 2 * (n_nodes - victims.min(n_nodes)) <= n_nodes {
            bail!(
                "fault plan removes {victims} of {n_nodes} ranks — \
                 the surviving set would lose quorum"
            );
        }
        Ok(())
    }

    /// A deterministic single-fault plan drawn from `seed`: victim,
    /// round, stage and kind are all pure functions of the seed (the
    /// chaos matrix sweeps seeds the way the hetero matrix sweeps speed
    /// palettes).
    pub fn from_seed(seed: u64, n_nodes: usize, lb_rounds: u32) -> FaultPlan {
        let mut plan = FaultPlan::none();
        if n_nodes < 3 || lb_rounds == 0 {
            return plan; // too small for any survivor quorum
        }
        let mut rng = Rng::new(seed ^ 0xFA01_7FA0);
        let victim = 1 + (rng.f64() * (n_nodes - 1) as f64) as u32;
        let victim = victim.min(n_nodes as u32 - 1);
        let lb_round = (rng.f64() * f64::from(lb_rounds)) as u32;
        let lb_round = lb_round.min(lb_rounds - 1);
        let stage = match (rng.f64() * 3.0) as u32 {
            0 => StagePoint::Handshake,
            1 => StagePoint::VirtualLb,
            _ => StagePoint::Selection,
        };
        plan.detect_ms = 500;
        plan.hang_ms = 4_000;
        plan.delay_ms = 100;
        match seed % 3 {
            0 => plan.events.push(FaultEvent {
                rank: victim,
                lb_round,
                stage,
                kind: FaultKind::Kill,
            }),
            1 => plan.events.push(FaultEvent {
                rank: victim,
                lb_round,
                stage,
                kind: FaultKind::Hang,
            }),
            // partitions must start at round >= 1 (see `validate`); a
            // one-round run degrades the partition draw to a kill
            _ if lb_rounds < 2 => {
                crate::obs::counter!("fault.partition_degraded").inc();
                crate::info!(
                    "fault plan seed {seed}: partition draw degraded to \
                     kill:{victim}@{lb_round} (run has {lb_rounds} LB round)"
                );
                plan.events.push(FaultEvent {
                    rank: victim,
                    lb_round,
                    stage,
                    kind: FaultKind::Kill,
                });
            }
            _ => plan.partitions.push(PartitionEvent {
                lb_round: lb_round.max(1),
                heal_round: None,
                minority: vec![victim],
            }),
        }
        plan
    }

    /// Parse a plan spec: comma-separated events, each
    /// `kill:RANK@ROUND[:STAGE]`, `hang:...`, `delay:...` or
    /// `part:R1|R2|...@ROUND[-HEAL]` (`-HEAL` lifts the cut at that LB
    /// round). Stages are `s1`/`s2`/`s3` (default `s2`).
    /// Example: `kill:2@1:s2,part:1|3@4,part:2@1-3`.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::none();
        plan.detect_ms = 500;
        plan.hang_ms = 4_000;
        plan.delay_ms = 100;
        for seg in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let (kind, rest) = seg
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("fault event '{seg}' missing ':'"))?;
            let (who, when) = rest
                .split_once('@')
                .ok_or_else(|| anyhow::anyhow!("fault event '{seg}' missing '@ROUND'"))?;
            if kind == "part" {
                let minority = who
                    .split('|')
                    .map(|r| r.trim().parse::<u32>())
                    .collect::<std::result::Result<Vec<u32>, _>>()
                    .map_err(|e| anyhow::anyhow!("bad partition ranks in '{seg}': {e}"))?;
                let (round_s, heal_s) = match when.split_once('-') {
                    Some((r, h)) => (r, Some(h)),
                    None => (when, None),
                };
                let lb_round: u32 = round_s
                    .parse()
                    .map_err(|e| anyhow::anyhow!("bad round in '{seg}': {e}"))?;
                let heal_round = heal_s
                    .map(|h| {
                        h.parse::<u32>()
                            .map_err(|e| anyhow::anyhow!("bad heal round in '{seg}': {e}"))
                    })
                    .transpose()?;
                plan.partitions.push(PartitionEvent { lb_round, heal_round, minority });
                continue;
            }
            let fk = match kind {
                "kill" => FaultKind::Kill,
                "hang" => FaultKind::Hang,
                "delay" => FaultKind::Delay,
                other => bail!("unknown fault kind '{other}' in '{seg}'"),
            };
            let rank: u32 =
                who.parse().map_err(|e| anyhow::anyhow!("bad rank in '{seg}': {e}"))?;
            let (round_s, stage) = match when.split_once(':') {
                Some((r, s)) => (r, StagePoint::parse(s)?),
                None => (when, StagePoint::VirtualLb),
            };
            let lb_round: u32 = round_s
                .parse()
                .map_err(|e| anyhow::anyhow!("bad round in '{seg}': {e}"))?;
            plan.events.push(FaultEvent { rank, lb_round, stage, kind: fk });
        }
        Ok(plan)
    }
}

impl Default for FaultPlan {
    fn default() -> FaultPlan {
        FaultPlan::none()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inert_plan_is_inactive() {
        let p = FaultPlan::none();
        assert!(!p.is_active());
        assert_eq!(p, FaultPlan::default());
        assert!(p.validate(4).is_ok());
        assert!(p.my_fault(1, 0).is_none());
        assert!(!p.cut(0, 1, 100));
    }

    #[test]
    fn parse_round_trips_the_kinds() {
        let p = FaultPlan::parse("kill:2@1:s2,hang:3@0:s1,delay:1@2:s3,part:1|3@4").unwrap();
        assert_eq!(p.events.len(), 3);
        assert_eq!(p.events[0].kind, FaultKind::Kill);
        assert_eq!(p.events[0].rank, 2);
        assert_eq!(p.events[0].lb_round, 1);
        assert_eq!(p.events[0].stage, StagePoint::VirtualLb);
        assert_eq!(p.events[1].stage, StagePoint::Handshake);
        assert_eq!(p.events[2].kind, FaultKind::Delay);
        assert_eq!(
            p.partitions,
            vec![PartitionEvent { lb_round: 4, heal_round: None, minority: vec![1, 3] }]
        );
        assert!(p.is_active());
        assert!(FaultPlan::parse("explode:2@1").is_err());
        assert!(FaultPlan::parse("kill:2").is_err());
    }

    #[test]
    fn parse_reads_heal_rounds() {
        let p = FaultPlan::parse("part:1|3@2-5").unwrap();
        assert_eq!(
            p.partitions,
            vec![PartitionEvent { lb_round: 2, heal_round: Some(5), minority: vec![1, 3] }]
        );
        assert!(FaultPlan::parse("part:1@2-x").is_err());
    }

    #[test]
    fn healed_cut_lifts_at_the_heal_round() {
        let p = FaultPlan::parse("part:1|3@2-4").unwrap();
        assert!(!p.cut(0, 1, 1), "inactive before its round");
        assert!(p.cut(0, 1, 2));
        assert!(p.cut(0, 1, 3));
        assert!(!p.cut(0, 1, 4), "healed at its heal round");
        assert!(!p.cut(0, 1, 9), "stays healed");
    }

    #[test]
    fn heal_helpers_track_exile_and_rejoin() {
        let p = FaultPlan::parse("part:1|3@2-4,part:2@1").unwrap();
        assert_eq!(p.healed_at(4), vec![1, 3]);
        assert!(p.healed_at(3).is_empty());
        assert_eq!(p.rejoined_mask(5, 3), vec![false; 5]);
        assert_eq!(p.rejoined_mask(5, 4), vec![false, true, false, true, false]);
        assert_eq!(p.exile_until(1, 2), Some(4));
        assert_eq!(p.exile_until(1, 3), Some(4));
        assert_eq!(p.exile_until(2, 1), None, "permanent partition never heals");
        assert_eq!(p.exile_until(0, 2), None, "majority side is not exiled");
        // a rank killed before its cut never rejoins at the heal
        let q = FaultPlan::parse("part:1@3-5,kill:1@1:s1").unwrap();
        assert!(q.healed_at(5).is_empty());
    }

    #[test]
    fn partition_cut_is_symmetric_and_clocked() {
        let p = FaultPlan::parse("part:1|3@2").unwrap();
        assert!(!p.cut(0, 1, 1), "inactive before its round");
        assert!(p.cut(0, 1, 2));
        assert!(p.cut(1, 0, 2));
        assert!(p.cut(2, 3, 5));
        assert!(!p.cut(1, 3, 2), "both in the minority: same side");
        assert!(!p.cut(0, 2, 2), "both in the majority: same side");
    }

    #[test]
    fn validate_accepts_coordinator_faults_and_rejects_quorum_loss() {
        // rank 0 is electable away now: coordinator faults are legal
        assert!(FaultPlan::parse("kill:0@1").unwrap().validate(4).is_ok());
        assert!(FaultPlan::parse("part:0@1").unwrap().validate(4).is_ok());
        assert!(FaultPlan::parse("kill:7@1").unwrap().validate(4).is_err());
        assert!(FaultPlan::parse("kill:1@0,kill:2@1").unwrap().validate(4).is_err());
        assert!(FaultPlan::parse("kill:1@0").unwrap().validate(4).is_ok());
        // delays don't remove a rank, so they never cost quorum
        assert!(FaultPlan::parse("delay:1@0,delay:2@0").unwrap().validate(4).is_ok());
    }

    #[test]
    fn validate_orders_heals_after_cuts() {
        assert!(FaultPlan::parse("part:1@2-2").unwrap().validate(4).is_err());
        assert!(FaultPlan::parse("part:1@3-2").unwrap().validate(4).is_err());
        assert!(FaultPlan::parse("part:1@2-3").unwrap().validate(4).is_ok());
        // a cut starting exactly at another's heal round is rejected:
        // the driver advances the fault clock early at heal rounds
        assert!(FaultPlan::parse("part:1@2-3,part:2@3-5").unwrap().validate(8).is_err());
        assert!(FaultPlan::parse("part:1@2-3,part:2@4-6").unwrap().validate(8).is_ok());
        // an event scheduled inside a rank's exile window can never
        // fire (the exile runs no pipeline stage): rejected
        assert!(FaultPlan::parse("part:1@2-4,kill:1@3").unwrap().validate(8).is_err());
        assert!(FaultPlan::parse("part:1@2,kill:1@5").unwrap().validate(8).is_err());
        assert!(FaultPlan::parse("part:1@3-5,kill:1@1").unwrap().validate(8).is_ok());
    }

    #[test]
    fn degraded_partition_draw_is_counted() {
        // seed % 3 == 2 draws a partition; lb_rounds == 1 degrades it
        // to a kill and must say so through obs.
        let seed = (0..64u64)
            .find(|s| {
                s % 3 == 2 && FaultPlan::from_seed(*s, 8, 3).partitions.len() == 1
            })
            .expect("some seed draws a partition");
        let before = crate::obs::registry::counter("fault.partition_degraded").get();
        let p = FaultPlan::from_seed(seed, 8, 1);
        let after = crate::obs::registry::counter("fault.partition_degraded").get();
        assert!(p.partitions.is_empty());
        assert_eq!(p.events.len(), 1);
        assert_eq!(p.events[0].kind, FaultKind::Kill);
        assert_eq!(after, before + 1, "degradation must bump the counter");
    }

    #[test]
    fn from_seed_is_deterministic_and_valid() {
        for seed in 0..24u64 {
            let a = FaultPlan::from_seed(seed, 8, 3);
            let b = FaultPlan::from_seed(seed, 8, 3);
            assert_eq!(a, b, "seed {seed} not deterministic");
            assert!(a.is_active(), "seed {seed} produced an empty plan");
            a.validate(8).unwrap();
        }
        // clusters too small for a survivor quorum get an inert plan
        assert!(!FaultPlan::from_seed(1, 2, 3).is_active());
    }
}
