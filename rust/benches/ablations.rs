//! Ablations over the design choices DESIGN.md calls out:
//! (a) object-selection overfill, (b) virtual-LB tolerance,
//! (c) neighbor-graph reuse across LB rounds (paper §III-A future
//! work), (d) SFC vs brute-force coordinate neighbor search (paper
//! §VII future work) — each swept on a fixed workload with the paper's
//! metrics. Output: tables + out/ablation_*.csv.

use std::time::Instant;

use difflb::apps::stencil::{inject_mod7, inject_noise, stencil_2d, stencil_3d, Decomposition};
use difflb::model::evaluate_mapping;
use difflb::strategies::diffusion::{neighbor, Diffusion};
use difflb::strategies::{LoadBalancer, StrategyParams};
use difflb::util::bench::Table;
use difflb::util::io::{out_path, CsvWriter};

fn main() -> anyhow::Result<()> {
    // ---------------- (a) overfill sweep
    {
        let mut inst = stencil_3d(16, 32);
        inject_mod7(&mut inst, 1.4, 0.6);
        let mut table = Table::new(
            "Ablation A: object-selection overfill (3D stencil, 32 PEs)",
            &["overfill", "max/avg", "ext/int", "% migrations"],
        );
        let mut csv = CsvWriter::create(
            out_path("ablation_overfill.csv")?,
            &["overfill", "max_avg", "ext_int", "migration_pct"],
        )?;
        for overfill in [0.0, 0.25, 0.5, 0.75] {
            let lb = Diffusion::communication(StrategyParams { overfill, ..Default::default() });
            let m = evaluate_mapping(&inst, &lb.rebalance(&inst).mapping);
            table.rowf(&[
                &overfill,
                &format!("{:.3}", m.max_avg_pe),
                &format!("{:.3}", m.comm_nodes.ratio()),
                &format!("{:.1}%", m.migration_pct),
            ]);
            csv.row(&[&overfill, &m.max_avg_pe, &m.comm_nodes.ratio(), &m.migration_pct])?;
        }
        csv.flush()?;
        println!("{}", table.render());
    }

    // ---------------- (b) virtual-LB tolerance sweep
    {
        let mut inst = stencil_3d(16, 32);
        inject_mod7(&mut inst, 1.4, 0.6);
        let mut table = Table::new(
            "Ablation B: virtual-LB neighborhood tolerance",
            &["tolerance", "max/avg", "% migrations", "vlb iterations"],
        );
        for tol in [0.01, 0.05, 0.1, 0.25] {
            let lb = Diffusion::communication(StrategyParams {
                vlb_tolerance: tol,
                ..Default::default()
            });
            let (_, quotas) = lb.plan(&inst);
            let m = evaluate_mapping(&inst, &lb.rebalance(&inst).mapping);
            table.rowf(&[
                &tol,
                &format!("{:.3}", m.max_avg_pe),
                &format!("{:.1}%", m.migration_pct),
                &quotas.iterations,
            ]);
        }
        println!("{}", table.render());
    }

    // ---------------- (c) neighbor-graph reuse across rounds
    {
        let mut table = Table::new(
            "Ablation C: neighbor-graph reuse over 5 drifting LB rounds",
            &["mode", "avg max/avg", "avg stage-1+plan time (µs)"],
        );
        for reuse in [false, true] {
            let mut inst = stencil_2d(48, 4, 4, Decomposition::Tiled);
            let lb = Diffusion::communication(StrategyParams {
                reuse_neighbors: reuse,
                ..Default::default()
            });
            let mut ratios = 0.0;
            let mut plan_us = 0.0;
            for round in 0..5u64 {
                inject_noise(&mut inst, 0.3, 77 + round);
                let t = Instant::now();
                let _ = lb.plan(&inst);
                plan_us += t.elapsed().as_secs_f64() * 1e6;
                let asg = lb.rebalance(&inst);
                ratios += evaluate_mapping(&inst, &asg.mapping).max_avg_node;
                inst.mapping = asg.mapping;
            }
            table.rowf(&[
                &(if reuse { "reuse" } else { "rebuild" }),
                &format!("{:.3}", ratios / 5.0),
                &format!("{:.0}", plan_us / 5.0),
            ]);
        }
        println!("{}", table.render());
        println!("(paper §III-A future work: comm patterns persist, so reuse should trade little quality for stage-1 cost)\n");
    }

    // ---------------- (d) SFC vs brute-force coordinate candidates
    {
        let mut table = Table::new(
            "Ablation D: coordinate neighbor search (64 PEs)",
            &["method", "candidates time (µs)", "max/avg after LB", "ext/int"],
        );
        let mut inst = stencil_2d(64, 8, 8, Decomposition::Tiled);
        inject_noise(&mut inst, 0.4, 9);
        let node_map = inst.node_mapping();
        for (label, window) in [("brute (O(n^2))", 0usize), ("sfc w=4", 4), ("sfc w=8", 8)] {
            let t = Instant::now();
            let reps = 50;
            for _ in 0..reps {
                if window == 0 {
                    std::hint::black_box(neighbor::coord_candidates(&inst, &node_map));
                } else {
                    std::hint::black_box(neighbor::coord_candidates_sfc(&inst, &node_map, window));
                }
            }
            let us = t.elapsed().as_secs_f64() * 1e6 / reps as f64;
            let lb = Diffusion::coordinate(StrategyParams {
                sfc_window: window,
                ..Default::default()
            });
            let m = evaluate_mapping(&inst, &lb.rebalance(&inst).mapping);
            table.rowf(&[
                &label,
                &format!("{us:.0}"),
                &format!("{:.3}", m.max_avg_node),
                &format!("{:.3}", m.comm_nodes.ratio()),
            ]);
        }
        println!("{}", table.render());
    }
    Ok(())
}
