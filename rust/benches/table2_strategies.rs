//! Table II — strategy comparison on three synthetic benchmarks with 3D
//! stencil communication patterns and mod-7 over/underload injection.
//!
//! Paper shape: GreedyRefine best max/avg (1.00) worst locality;
//! METIS best locality but ~87-99% migrations; ParMETIS tunable middle;
//! the diffusion variants land between — good balance, near-initial
//! locality, ~15-19% migrations.

use difflb::apps::stencil::{inject_mod7, stencil_3d};
use difflb::model::{evaluate_mapping, Instance};
use difflb::strategies::{make, StrategyParams};
use difflb::util::bench::Table;
use difflb::util::io::{out_path, CsvWriter};

const STRATEGIES: &[(&str, &str)] = &[
    ("greedy-refine", "GreedyRefine"),
    ("metis", "METIS"),
    ("parmetis", "ParMETIS"),
    ("diff-comm", "Diff-Comm"),
    ("diff-coord", "Diff-Coord"),
];

fn benchmark(idx: usize, pes: usize, side: usize) -> anyhow::Result<()> {
    let mut inst: Instance = stencil_3d(side, pes);
    inject_mod7(&mut inst, 1.4, 0.6);
    let initial = evaluate_mapping(&inst, &inst.mapping);

    let mut table = Table::new(
        format!("Table II Benchmark {idx}: {pes} PEs ({}^3 = {} objects)", side, inst.n_objects()),
        &["metric", "Initial", "GreedyRefine", "METIS", "ParMETIS", "Diff-Comm", "Diff-Coord"],
    );
    let mut r_load = vec!["max/avg load".to_string(), format!("{:.2}", initial.max_avg_pe)];
    let mut r_comm = vec![
        "ext/int comm (MB)".to_string(),
        format!("{:.3}", initial.comm_nodes.external / 1e6),
    ];
    let mut r_ratio = vec![
        "ext/int ratio".to_string(),
        format!("{:.3}", initial.comm_nodes.ratio()),
    ];
    let mut r_migr = vec!["% migrations".to_string(), "-".to_string()];

    let mut csv = CsvWriter::create(
        out_path(&format!("table2_bench{idx}.csv"))?,
        &["strategy", "max_avg", "ext_mb", "ext_int_ratio", "migration_pct", "lb_ms"],
    )?;
    csv.row(&[
        &"initial",
        &initial.max_avg_pe,
        &(initial.comm_nodes.external / 1e6),
        &initial.comm_nodes.ratio(),
        &0.0,
        &0.0,
    ])?;

    for (name, _label) in STRATEGIES {
        let lb = make(name, StrategyParams::default())?;
        let t = std::time::Instant::now();
        let asg = lb.rebalance(&inst);
        let ms = t.elapsed().as_secs_f64() * 1e3;
        let m = evaluate_mapping(&inst, &asg.mapping);
        r_load.push(format!("{:.2}", m.max_avg_pe));
        r_comm.push(format!("{:.3}", m.comm_nodes.external / 1e6));
        r_ratio.push(format!("{:.3}", m.comm_nodes.ratio()));
        r_migr.push(format!("{:.1}%", m.migration_pct));
        csv.row(&[
            name,
            &m.max_avg_pe,
            &(m.comm_nodes.external / 1e6),
            &m.comm_nodes.ratio(),
            &m.migration_pct,
            &ms,
        ])?;
    }
    csv.flush()?;
    table.row(&r_load);
    table.row(&r_comm);
    table.row(&r_ratio);
    table.row(&r_migr);
    println!("{}", table.render());
    Ok(())
}

fn main() -> anyhow::Result<()> {
    // Benchmark 1/2/3: 8 / 32 / 128 PEs at increasing scale.
    benchmark(1, 8, 16)?;
    benchmark(2, 32, 16)?;
    benchmark(3, 128, 32)?;
    println!(
        "paper Table II shape: GreedyRefine max/avg=1.00 & worst locality; METIS best \
         locality & 87-99% migrations; diffusion in between with ~15-19% migrations"
    );
    Ok(())
}
