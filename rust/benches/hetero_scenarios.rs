//! Bench smoke for heterogeneous clusters (ISSUE 5 satellite): the
//! speed-aware pipeline across the three scenario families the CI
//! heterogeneity matrix sweeps — uniform (the legacy bit-path), static
//! mixed speeds, and a noisy (per-iteration perturbed) schedule —
//! plus the incremental cost of the weighted arithmetic on the
//! strategy hot path itself.
//!
//! Writes `BENCH_hetero.json` (override with `DIFFLB_BENCH_JSON`;
//! shrink the per-path budget with `DIFFLB_BENCH_BUDGET_MS`).

use std::time::Duration;

use difflb::apps::driver::{run_app, DriverConfig};
use difflb::apps::hotspot::{Hotspot, HotspotConfig};
use difflb::apps::stencil::{self, Decomposition};
use difflb::model::{SpeedSchedule, Topology};
use difflb::strategies::diffusion::Diffusion;
use difflb::strategies::{make, LoadBalancer, StrategyParams};
use difflb::util::bench::{time_fn, JsonReport, Timing};

struct Report {
    json: JsonReport,
}

impl Report {
    fn record(&mut self, t: &Timing, throughput: Option<(&str, f64)>) {
        let extra = match throughput {
            Some((unit, v)) => format!("{v:.1} {unit}"),
            None => String::new(),
        };
        println!("{}  {extra}", t.report());
        self.json.add(t, throughput);
    }
}

/// Cycled speed palette — the same shape the tests use.
fn mixed_speeds(n_pes: usize) -> Vec<f64> {
    const PALETTE: [f64; 4] = [1.0, 2.0, 0.5, 1.5];
    (0..n_pes).map(|pe| PALETTE[pe % PALETTE.len()]).collect()
}

fn main() -> anyhow::Result<()> {
    let budget_ms: u64 = std::env::var("DIFFLB_BENCH_BUDGET_MS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(400);
    let budget = Duration::from_millis(budget_ms);
    let mut rep = Report { json: JsonReport::new() };

    // ---------- strategy hot path: rebalance cost, uniform vs weighted
    // (the weighted arithmetic must stay noise-level on the profile).
    let mk_inst = |hetero: bool| {
        let mut inst = stencil::stencil_2d(48, 4, 4, Decomposition::Tiled);
        stencil::inject_noise(&mut inst, 0.4, 0x4E7E);
        if hetero {
            inst.topo = inst.topo.clone().with_pe_speeds(mixed_speeds(16));
        }
        inst
    };
    for (label, hetero) in [("uniform", false), ("mixed-speed", true)] {
        let inst = mk_inst(hetero);
        let lb = Diffusion::communication(StrategyParams::default());
        let t = time_fn(
            &format!("diffusion rebalance {label} (2304 obj, 16 nodes)"),
            budget,
            || lb.rebalance(&inst).mapping.len(),
        );
        rep.record(&t, Some(("rebalances/s", 1.0 / t.mean_s)));
    }
    for (label, hetero) in [("uniform", false), ("mixed-speed", true)] {
        let inst = mk_inst(hetero);
        let lb: Box<dyn LoadBalancer> =
            make("greedy-refine", StrategyParams::default()).unwrap();
        let t = time_fn(
            &format!("greedy-refine rebalance {label} (2304 obj)"),
            budget,
            || lb.rebalance(&inst).mapping.len(),
        );
        rep.record(&t, None);
    }

    // ---------- scenario family: hotspot runs through the generic
    // driver under uniform / mixed / noisy schedules.
    let scenarios: [(&str, Option<Vec<f64>>, SpeedSchedule); 3] = [
        ("uniform", None, SpeedSchedule::none()),
        ("mixed-speed", Some(mixed_speeds(4)), SpeedSchedule::none()),
        (
            "noisy",
            Some(mixed_speeds(4)),
            SpeedSchedule { noise: 0.3, period: 2, seed: 0xA11 },
        ),
    ];
    for (label, speeds, sched) in scenarios {
        let topo = match &speeds {
            None => Topology::flat(4),
            Some(s) => Topology::flat(4).with_pe_speeds(s.clone()),
        };
        let driver = DriverConfig {
            iters: 20,
            lb_period: 5,
            deterministic_loads: true,
            speed_schedule: sched,
            ..Default::default()
        };
        let t = time_fn(
            &format!("hotspot run_app 20 iters diff-comm ({label})"),
            budget,
            || {
                let mut app = Hotspot::new(HotspotConfig {
                    topo: topo.clone(),
                    ..Default::default()
                })
                .unwrap();
                let strat = make("diff-comm", StrategyParams::default()).unwrap();
                run_app(&mut app, strat.as_ref(), &driver).unwrap().total_migrations
            },
        );
        rep.record(&t, None);
    }

    let out = std::env::var("DIFFLB_BENCH_JSON").unwrap_or_else(|_| {
        format!("{}/../BENCH_hetero.json", env!("CARGO_MANIFEST_DIR"))
    });
    let label = format!(
        "hetero_scenarios budget={budget_ms}ms threads={}",
        std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1)
    );
    rep.json.write(&out, &label)?;
    println!("wrote {out} ({} paths)", rep.json.len());
    Ok(())
}
