//! Table I — impact of neighbor count K on load-balancing quality.
//!
//! Paper setup: processors form a 1D ring, one processor overloaded
//! 10x (initial max/avg ≈ 5); diffusion with K ∈ {1, 2, 4, 8}.
//! Expected shape: K=1 cannot shed load (l/2 = 0 sends no requests),
//! balance improves monotonically with K, while external/internal
//! communication grows as more-distant migrations open up.

use difflb::apps::stencil::{overload_pe, ring};
use difflb::model::evaluate_mapping;
use difflb::strategies::{make, StrategyParams};
use difflb::util::bench::Table;
use difflb::util::io::{out_path, CsvWriter};

fn main() -> anyhow::Result<()> {
    let n_pes = 10;
    let objs_per_pe = 16;

    let mut table = Table::new(
        format!("Table I: 1D ring, {n_pes} PEs, one overloaded 10x (diff-comm)"),
        &["metric", "K=1", "K=2", "K=4", "K=8"],
    );
    let mut ratios = vec!["max/avg load".to_string()];
    let mut comms = vec!["external/internal comm".to_string()];
    let mut migrs = vec!["% migrations".to_string()];
    let mut csv = CsvWriter::create(
        out_path("table1.csv")?,
        &["k", "max_avg", "ext_int", "migration_pct", "initial_max_avg", "initial_ext_int"],
    )?;

    for k in [1usize, 2, 4, 8] {
        let mut inst = ring(n_pes, objs_per_pe);
        overload_pe(&mut inst, 0, 10.0);
        let initial = evaluate_mapping(&inst, &inst.mapping);
        let params = StrategyParams { neighbor_count: k, ..Default::default() };
        let lb = make("diff-comm", params)?;
        let asg = lb.rebalance(&inst);
        let m = evaluate_mapping(&inst, &asg.mapping);
        ratios.push(format!("{:.2}", m.max_avg_pe));
        comms.push(format!("{:.3}", m.comm_nodes.ratio()));
        migrs.push(format!("{:.1}%", m.migration_pct));
        csv.row(&[
            &k,
            &m.max_avg_pe,
            &m.comm_nodes.ratio(),
            &m.migration_pct,
            &initial.max_avg_pe,
            &initial.comm_nodes.ratio(),
        ])?;
    }
    csv.flush()?;
    table.row(&ratios);
    table.row(&comms);
    table.row(&migrs);
    println!("{}", table.render());
    println!("paper Table I: max/avg 4.9 / 1.7 / 1.3 / 1.1, ext/int .142 / .151 / .25 / .26");
    println!("series: out/table1.csv");
    Ok(())
}
