//! §Perf — the `.lbi` wire path in isolation: text serialize/parse vs
//! the binary codec ([`difflb::model::lbi`]), across instance sizes.
//! The distributed driver broadcasts one encode and pays one decode per
//! participating rank every LB round, so this is the per-round protocol
//! overhead. Writes `BENCH_lbi.json` (override with `DIFFLB_BENCH_JSON`,
//! shrink budgets with `DIFFLB_BENCH_BUDGET_MS`) for
//! `tools/bench_gate.py`.

use std::time::Duration;

use difflb::apps::stencil::{self, Decomposition};
use difflb::model::{decode_lbi, encode_lbi, Instance};
use difflb::util::bench::{time_fn, JsonReport, Timing};

struct Report {
    json: JsonReport,
}

impl Report {
    fn record(&mut self, t: &Timing, throughput: Option<(&str, f64)>) {
        let extra = match throughput {
            Some((unit, v)) => format!("{v:.1} {unit}"),
            None => String::new(),
        };
        println!("{}  {extra}", t.report());
        self.json.add(t, throughput);
    }
}

fn main() -> anyhow::Result<()> {
    let budget_ms: u64 = std::env::var("DIFFLB_BENCH_BUDGET_MS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(400);
    let budget = Duration::from_millis(budget_ms);
    let mut rep = Report { json: JsonReport::new() };

    // (grid, nodes_x, nodes_y): 1k / 9k / 36k objects with real stencil
    // comm graphs — edge density matches what the driver broadcasts.
    for (grid, nx, ny) in [(32usize, 4usize, 4usize), (96, 8, 8), (192, 8, 8)] {
        let mut inst = stencil::stencil_2d(grid, nx, ny, Decomposition::Tiled);
        stencil::inject_noise(&mut inst, 0.4, 7);
        let n = inst.n_objects();

        let t = time_fn(&format!("lbi text serialize n={n}"), budget, || inst.to_lbi().len());
        rep.record(&t, None);
        let text = inst.to_lbi();
        let t = time_fn(&format!("lbi text parse n={n}"), budget, || {
            Instance::from_lbi(&text).unwrap().n_objects()
        });
        rep.record(&t, None);

        let t = time_fn(&format!("lbi binary encode n={n}"), budget, || encode_lbi(&inst).len());
        rep.record(&t, None);
        let wire = encode_lbi(&inst);
        let t = time_fn(&format!("lbi binary decode n={n}"), budget, || {
            decode_lbi(&wire).unwrap().n_objects()
        });
        let mbs = wire.len() as f64 / t.mean_s / 1e6;
        rep.record(&t, Some(("MB/s", mbs)));
        println!(
            "  wire sizes n={n}: text {} B, binary {} B ({:.2}x)",
            text.len(),
            wire.len(),
            text.len() as f64 / wire.len() as f64
        );
    }

    let out = std::env::var("DIFFLB_BENCH_JSON")
        .unwrap_or_else(|_| format!("{}/../BENCH_lbi.json", env!("CARGO_MANIFEST_DIR")));
    let label = format!(
        "lbi_codec budget={budget_ms}ms threads={}",
        std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1)
    );
    rep.json.write(&out, &label)?;
    println!("wrote {out} ({} paths)", rep.json.len());
    Ok(())
}
