//! Fig 2 — object migration in a 2D stencil benchmark: 16 processors,
//! tiled initial decomposition, each object's load randomly perturbed
//! ±40%, both diffusion variants with K = 4 neighbors.
//!
//! Paper numbers: coordinate-based (max/avg 1.02, ext/int .072),
//! communication-based (1.04, .06) — comm preserves domain shapes and
//! the periodic boundary, coord rounds borders and misses it.
//!
//! Outputs: out/fig2_{initial,comm,coord}.{ppm,svg} + out/fig2.csv

use difflb::apps::stencil::{inject_noise_binary, stencil_2d, Decomposition};
use difflb::model::evaluate_mapping;
use difflb::strategies::{make, StrategyParams};
use difflb::util::bench::Table;
use difflb::util::io::{out_path, CsvWriter};
use difflb::viz;

fn main() -> anyhow::Result<()> {
    let side = 32; // 1024 objects over 16 PEs (64 per PE)
    let mut inst = stencil_2d(side, 4, 4, Decomposition::Tiled);
    inject_noise_binary(&mut inst, 0.4, 0xF162);
    let initial = evaluate_mapping(&inst, &inst.mapping);
    let scale = (768 / side).max(4) as f64;

    viz::render_ppm(&inst, &inst.mapping, scale, out_path("fig2_initial.ppm")?)?;
    viz::render_svg(&inst, &inst.mapping, scale, out_path("fig2_initial.svg")?)?;

    let params = StrategyParams { neighbor_count: 4, ..Default::default() };
    let mut table = Table::new(
        "Fig 2: 2D stencil, 16 PEs, tiled init, ±40% load noise, K=4",
        &["variant", "max/avg load", "ext/int comm", "% migrations"],
    );
    table.rowf(&[
        &"initial",
        &format!("{:.3}", initial.max_avg_node),
        &format!("{:.3}", initial.comm_nodes.ratio()),
        &"-",
    ]);
    let mut csv = CsvWriter::create(
        out_path("fig2.csv")?,
        &["variant", "max_avg", "ext_int", "migration_pct"],
    )?;
    csv.row(&[&"initial", &initial.max_avg_node, &initial.comm_nodes.ratio(), &0.0])?;

    for (label, name) in [("coord", "diff-coord"), ("comm", "diff-comm")] {
        let asg = make(name, params)?.rebalance(&inst);
        let m = evaluate_mapping(&inst, &asg.mapping);
        table.rowf(&[
            &label,
            &format!("{:.3}", m.max_avg_node),
            &format!("{:.3}", m.comm_nodes.ratio()),
            &format!("{:.1}%", m.migration_pct),
        ]);
        csv.row(&[&label, &m.max_avg_node, &m.comm_nodes.ratio(), &m.migration_pct])?;
        viz::render_ppm(&inst, &asg.mapping, scale, out_path(&format!("fig2_{label}.ppm"))?)?;
        viz::render_svg(&inst, &asg.mapping, scale, out_path(&format!("fig2_{label}.svg"))?)?;
    }
    csv.flush()?;
    println!("{}", table.render());
    println!("paper Fig 2: coord (1.02, .072) vs comm (1.04, .06)");
    println!("images: out/fig2_*.ppm/svg, series: out/fig2.csv");
    Ok(())
}
