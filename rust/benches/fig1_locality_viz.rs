//! Fig 1 — load/locality visualization of a 2D stencil application:
//! contiguous same-color blocks (diffusion, good locality) vs dispersed
//! objects (greedy-refine / scatter, disrupted locality).
//!
//! Outputs: out/fig1_{initial,diffusion,greedy_refine,scatter}.{ppm,svg}

use difflb::apps::stencil::{inject_noise, stencil_2d, Decomposition};
use difflb::model::evaluate_mapping;
use difflb::strategies::{make, StrategyParams};
use difflb::util::io::out_path;
use difflb::viz;

fn main() -> anyhow::Result<()> {
    let side = 32;
    let mut inst = stencil_2d(side, 4, 4, Decomposition::Tiled);
    inject_noise(&mut inst, 0.4, 0xF16);
    let scale = 16.0;

    let mut render = |label: &str, mapping: &[u32]| -> anyhow::Result<()> {
        let m = evaluate_mapping(&inst, mapping);
        println!(
            "{label:<14} max/avg={:.3} ext/int={:.3} migr={:.1}%",
            m.max_avg_node,
            m.comm_nodes.ratio(),
            m.migration_pct
        );
        viz::render_ppm(&inst, mapping, scale, out_path(&format!("fig1_{label}.ppm"))?)?;
        viz::render_svg(&inst, mapping, scale, out_path(&format!("fig1_{label}.svg"))?)?;
        Ok(())
    };

    render("initial", &inst.mapping.clone())?;
    for (label, name) in [
        ("diffusion", "diff-comm"),
        ("greedy_refine", "greedy-refine"),
        ("scatter", "scatter"),
    ] {
        let asg = make(name, StrategyParams::default())?.rebalance(&inst);
        render(label, &asg.mapping)?;
    }
    println!("wrote out/fig1_*.ppm/svg — diffusion keeps contiguous color blocks, scatter disperses them");
    Ok(())
}
