//! Fig 5 — strong scaling of PIC PRK, 1-8 nodes × 16 processes,
//! comparing Diffusion, GreedyRefine, and no load balancing, with the
//! total / communication / LB time breakdown.
//!
//! Paper setup: 10M particles, 6000x6000 grid, k=4, rho=0.9, 200x100
//! chares, Perlmutter. Here the same workload runs on the simulated
//! cluster: computation is real (measured native push), communication
//! and migration transfer are modeled with the α–β NetModel (see
//! DESIGN.md substitutions). Default is a scaled-down workload;
//! DIFFLB_FULL=1 runs the paper-size one.
//!
//! Expected shape: no-LB doesn't scale at all; Diffusion beats
//! GreedyRefine everywhere with the gap widening at scale (paper: 2x
//! over GreedyRefine and 7x over no-LB at 8 nodes).

use difflb::apps::driver::{run_app, DriverConfig};
use difflb::apps::pic::{Backend, InitMode, PicApp, PicConfig};
use difflb::apps::stencil::Decomposition;
use difflb::model::Topology;
use difflb::strategies::{make, StrategyParams};
use difflb::util::bench::Table;
use difflb::util::io::{out_path, CsvWriter};

fn main() -> anyhow::Result<()> {
    let full = std::env::var("DIFFLB_FULL").is_ok();
    // scaled: 1M particles on 2000^2; full: paper's 10M on 6000^2
    let (grid, particles, iters) = if full { (6000, 10_000_000, 100) } else { (2000, 1_000_000, 100) };
    let (chares_x, chares_y) = if full { (200, 100) } else { (100, 50) };
    let procs_per_node = 16;

    let mut table = Table::new(
        format!(
            "Fig 5: strong scaling, {particles} particles, {grid}^2 grid, k=4, rho=.9, \
             {chares_x}x{chares_y} chares, 16 procs/node{}",
            if full { " (FULL)" } else { " (scaled; DIFFLB_FULL=1 for paper size)" }
        ),
        &["nodes", "strategy", "total(s)", "compute(s)", "comm(s)", "lb(s)", "speedup-vs-none"],
    );
    let mut csv = CsvWriter::create(
        out_path("fig5.csv")?,
        &["nodes", "strategy", "total_s", "compute_s", "comm_s", "lb_s"],
    )?;

    for nodes in [1usize, 2, 4, 8] {
        let mk = |seed: u64| PicConfig {
            grid,
            n_particles: particles,
            k: 4,
            m: 1,
            init: InitMode::Geometric { rho: 0.9 },
            chares_x,
            chares_y,
            decomp: Decomposition::Striped,
            topo: Topology::flat(nodes * procs_per_node),
            q: 1.0,
            seed,
            particle_bytes: 80.0,
            threads: 8,
        };
        let driver = DriverConfig {
            iters,
            lb_period: 5,
            net: difflb::simnet::NetModel { alpha: 2e-5, beta: 5e-10, intra_factor: 0.05 },
            ..Default::default()
        };
        let mut none_total = 0.0;
        for name in ["none", "greedy-refine", "diff-comm"] {
            let mut app = PicApp::new(mk(0x515), Backend::Native)?;
            let strat = make(name, StrategyParams::default())?;
            let rep = run_app(&mut app, strat.as_ref(), &driver)?;
            anyhow::ensure!(rep.verified, "fig5 verification failed: {name}/{nodes}");
            if name == "none" {
                none_total = rep.total_s;
            }
            table.rowf(&[
                &nodes,
                &name,
                &format!("{:.3}", rep.total_s),
                &format!("{:.3}", rep.compute_s),
                &format!("{:.3}", rep.comm_s),
                &format!("{:.3}", rep.lb_s),
                &format!("{:.2}x", none_total / rep.total_s),
            ]);
            csv.row(&[&nodes, &name, &rep.total_s, &rep.compute_s, &rep.comm_s, &rep.lb_s])?;
        }
    }
    csv.flush()?;
    println!("{}", table.render());
    println!(
        "paper Fig 5: no-LB does not scale; Diffusion > GreedyRefine at every scale, \
         gap widening; at 8 nodes Diffusion ≈2x GreedyRefine, ≈7x no-LB"
    );
    println!("series: out/fig5.csv");
    Ok(())
}
