//! Fig 3 + Fig 4 — PIC PRK particle distribution over time.
//!
//! Fig 3: particles per processor over 200 iterations with NO load
//! balancing (k=2, rho=0.9, 4 PEs, striped) — the rightward-sweeping
//! imbalance wave. Fig 4: max/avg particles per PE over 100 iterations
//! under none / GreedyRefine / Diff-Comm / Diff-Coord, LB every 10
//! iterations, K=4. Paper: GreedyRefine and Diff-Coord ≈50%
//! improvement, Diff-Comm ≈48% on average.
//!
//! Outputs: out/fig3.csv, out/fig4.csv + summary table.

use difflb::apps::driver::{run_app, DriverConfig};
use difflb::apps::pic::{Backend, InitMode, PicApp, PicConfig};
use difflb::apps::stencil::Decomposition;
use difflb::model::Topology;
use difflb::strategies::{make, StrategyParams};
use difflb::util::bench::Table;
use difflb::util::io::{out_path, CsvWriter};

fn cfg() -> PicConfig {
    PicConfig {
        grid: 1000,
        n_particles: 100_000,
        k: 2,
        m: 1,
        init: InitMode::Geometric { rho: 0.9 },
        chares_x: 12,
        chares_y: 12,
        decomp: Decomposition::Striped,
        topo: Topology::flat(4),
        q: 1.0,
        seed: 0x34,
        particle_bytes: 48.0,
        threads: 8,
    }
}

fn main() -> anyhow::Result<()> {
    // grid=1000 with 12x12 chares needs divisibility: use 996? The
    // paper used 1000x1000 with 12x12 chares (~83x83 cells). We use
    // 996 (83 * 12) to keep exact tiling.
    let mut base = cfg();
    base.grid = 996;

    // ---------------- Fig 3: no LB, 200 iterations, particles per PE.
    {
        let mut app = PicApp::new(base.clone(), Backend::Native)?;
        let strat = make("none", StrategyParams::default())?;
        let driver = DriverConfig { iters: 200, lb_period: 0, ..Default::default() };
        let rep = run_app(&mut app, strat.as_ref(), &driver)?;
        anyhow::ensure!(rep.verified, "fig3 physics verification failed");
        let mut csv = CsvWriter::create(
            out_path("fig3.csv")?,
            &["iter", "pe0", "pe1", "pe2", "pe3"],
        )?;
        for r in &rep.records {
            csv.row(&[
                &r.iter,
                &r.node_work[0],
                &r.node_work[1],
                &r.node_work[2],
                &r.node_work[3],
            ])?;
        }
        csv.flush()?;
        // sanity summary: which PE peaked when
        let peak_iter = |pe: usize| {
            rep.records
                .iter()
                .max_by(|a, b| a.node_work[pe].total_cmp(&b.node_work[pe]))
                .map(|r| r.iter)
                .unwrap_or(0)
        };
        println!(
            "Fig 3 (out/fig3.csv): particle wave peaks at iters {:?} for PEs 0..3 — \
             the rightward sweep",
            (0..4).map(peak_iter).collect::<Vec<_>>()
        );
    }

    // ---------------- Fig 4: strategies, 100 iters, LB every 10, K=4.
    {
        let params = StrategyParams { neighbor_count: 4, ..Default::default() };
        let driver = DriverConfig { iters: 100, lb_period: 10, ..Default::default() };
        let names = ["none", "greedy-refine", "diff-comm", "diff-coord"];
        let mut series: Vec<Vec<f64>> = Vec::new();
        for name in names {
            let mut app = PicApp::new(base.clone(), Backend::Native)?;
            let strat = make(name, params)?;
            let rep = run_app(&mut app, strat.as_ref(), &driver)?;
            anyhow::ensure!(rep.verified, "fig4 physics verification failed under {name}");
            series.push(rep.records.iter().map(|r| r.work_max_avg).collect());
        }
        let mut csv = CsvWriter::create(
            out_path("fig4.csv")?,
            &["iter", "none", "greedy_refine", "diff_comm", "diff_coord"],
        )?;
        for i in 0..100 {
            csv.row_f64(&[i as f64, series[0][i], series[1][i], series[2][i], series[3][i]])?;
        }
        csv.flush()?;

        let avg = |s: &[f64]| s.iter().sum::<f64>() / s.len() as f64;
        let base_avg = avg(&series[0]);
        let mut table = Table::new(
            "Fig 4: avg max/avg particles per PE (100 iters, LB every 10, K=4)",
            &["strategy", "avg max/avg", "improvement vs none"],
        );
        for (i, name) in names.iter().enumerate() {
            let a = avg(&series[i]);
            table.rowf(&[
                name,
                &format!("{a:.3}"),
                &format!("{:.1}%", 100.0 * (1.0 - a / base_avg)),
            ]);
        }
        println!("{}", table.render());
        println!("paper Fig 4: GreedyRefine/Diff-Coord ≈50%, Diff-Comm ≈48% improvement");
        println!("series: out/fig4.csv");
    }
    Ok(())
}
