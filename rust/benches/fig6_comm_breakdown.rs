//! Fig 6 — communication and computation time per process over 100 LB
//! phases on 8 nodes (LB every 5 iterations), Diffusion vs GreedyRefine.
//!
//! Paper shape: GreedyRefine shows comm-time spikes and ~2x higher max
//! communication time than Diffusion; average computation time is the
//! same under both but Diffusion's max computation time is ~2.5x
//! better (more consistent balance across iterations).
//!
//! Outputs: out/fig6_<strategy>.csv + summary ratios.

use difflb::apps::driver::{run_app, DriverConfig};
use difflb::apps::pic::{Backend, InitMode, PicApp, PicConfig};
use difflb::apps::stencil::Decomposition;
use difflb::model::Topology;
use difflb::strategies::{make, StrategyParams};
use difflb::util::bench::Table;
use difflb::util::io::{out_path, CsvWriter};

fn main() -> anyhow::Result<()> {
    let full = std::env::var("DIFFLB_FULL").is_ok();
    let phases = if full { 100 } else { 40 };
    let lb_period = 5;
    let (grid, particles) = if full { (6000, 10_000_000) } else { (2000, 1_000_000) };
    let (chares_x, chares_y) = if full { (200, 100) } else { (100, 50) };
    let nodes = 8 * 16; // 8 nodes x 16 processes

    let driver = DriverConfig {
        iters: phases * lb_period,
        lb_period,
        net: difflb::simnet::NetModel { alpha: 2e-5, beta: 5e-10, intra_factor: 0.05 },
        ..Default::default()
    };
    let mut results = Vec::new();
    for name in ["diff-comm", "greedy-refine"] {
        let cfg = PicConfig {
            grid,
            n_particles: particles,
            k: 4,
            m: 1,
            init: InitMode::Geometric { rho: 0.9 },
            chares_x,
            chares_y,
            decomp: Decomposition::Striped,
            topo: Topology::flat(nodes),
            q: 1.0,
            seed: 0xF16,
            particle_bytes: 80.0,
            threads: 8,
        };
        let mut app = PicApp::new(cfg, Backend::Native)?;
        let strat = make(name, StrategyParams::default())?;
        let rep = run_app(&mut app, strat.as_ref(), &driver)?;
        anyhow::ensure!(rep.verified, "fig6 verification failed under {name}");
        let mut csv = CsvWriter::create(
            out_path(&format!("fig6_{name}.csv"))?,
            &["iter", "comm_max_s", "comm_avg_s", "compute_max_s", "compute_avg_s", "lb_s"],
        )?;
        for r in &rep.records {
            csv.row(&[
                &r.iter,
                &r.comm_max_s,
                &r.comm_avg_s,
                &r.compute_max_s,
                &r.compute_avg_s,
                &r.lb_s,
            ])?;
        }
        csv.flush()?;
        results.push((name, rep));
    }

    let avg = |xs: &[f64]| xs.iter().sum::<f64>() / xs.len().max(1) as f64;
    let series = |rep: &difflb::apps::driver::RunReport, f: fn(&difflb::apps::driver::IterRecord) -> f64| {
        rep.records.iter().map(f).collect::<Vec<f64>>()
    };

    let mut table = Table::new(
        format!("Fig 6: 8 nodes x 16 procs, {phases} LB phases, LB every {lb_period}"),
        &["strategy", "avg max-comm (ms)", "avg max-compute (ms)", "avg avg-compute (ms)"],
    );
    for (name, rep) in &results {
        table.rowf(&[
            name,
            &format!("{:.3}", 1e3 * avg(&series(rep, |r| r.comm_max_s))),
            &format!("{:.3}", 1e3 * avg(&series(rep, |r| r.compute_max_s))),
            &format!("{:.3}", 1e3 * avg(&series(rep, |r| r.compute_avg_s))),
        ]);
    }
    println!("{}", table.render());

    let (d, g) = (&results[0].1, &results[1].1);
    let comm_speedup =
        avg(&series(g, |r| r.comm_max_s)) / avg(&series(d, |r| r.comm_max_s)).max(1e-12);
    let comp_speedup =
        avg(&series(g, |r| r.compute_max_s)) / avg(&series(d, |r| r.compute_max_s)).max(1e-12);
    println!(
        "diffusion speedup over greedy-refine: {comm_speedup:.2}x max-comm, \
         {comp_speedup:.2}x max-compute (paper: ≈2x and ≈2.5x)"
    );
    println!("series: out/fig6_diff-comm.csv, out/fig6_greedy-refine.csv");
    Ok(())
}
