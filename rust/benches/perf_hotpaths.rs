//! §Perf — micro-benchmarks of every hot path in the stack, feeding
//! EXPERIMENTS.md §Perf: the native particle push (throughput), the
//! PJRT kernel path (dispatch + execute), the three diffusion stages,
//! the baselines, and the metrics/instance plumbing.

use std::time::Duration;

use difflb::apps::pic::init::{initialize, InitMode};
use difflb::apps::pic::push::native_push;
use difflb::apps::pic::{Backend, PicApp, PicConfig};
use difflb::apps::stencil::{self, Decomposition};
use difflb::model::{evaluate_mapping, Topology};
use difflb::runtime::{Engine, Manifest, PicBatch};
use difflb::strategies::diffusion::{neighbor, virtual_lb, Diffusion};
use difflb::strategies::{make, StrategyParams};
use difflb::util::bench::{time_fn, Timing};

fn report(t: &Timing, extra: &str) {
    println!("{}  {extra}", t.report());
}

fn main() -> anyhow::Result<()> {
    let budget = Duration::from_millis(400);

    // ---------- L1/L2 surrogate + L3 compute: particle push
    let n = 65_536;
    let pop = initialize(InitMode::Geometric { rho: 0.9 }, n, 1000, 2, 1, 1.0, 1);
    let base = PicBatch { x: pop.x, y: pop.y, vx: pop.vx, vy: pop.vy, q: pop.q };
    for threads in [1usize, 4, 8] {
        let mut b = base.clone();
        let t = time_fn(&format!("native_push n={n} threads={threads}"), budget, || {
            native_push(&mut b, 1000.0, 1.0, threads);
            b.x[0]
        });
        report(&t, &format!("{:.1} Mparticles/s", n as f64 / t.mean_s / 1e6));
    }
    if let Ok(m) = Manifest::load_default() {
        let engine = Engine::with_manifest(m)?;
        let mut b = base.clone();
        let t = time_fn(&format!("pjrt_push n={n}"), budget, || {
            engine.pic_push(&mut b, 1000.0, 1.0).unwrap();
            b.x[0]
        });
        report(&t, &format!("{:.1} Mparticles/s", n as f64 / t.mean_s / 1e6));
        // stencil artifact
        let grid: Vec<f64> = (0..256 * 256).map(|i| i as f64).collect();
        let t = time_fn("pjrt_stencil 256x256", budget, || {
            engine.stencil_step(&grid, 256, 256, 0.2).unwrap()[0]
        });
        report(&t, &format!("{:.1} Mcell/s", 256.0 * 256.0 / t.mean_s / 1e6));
    } else {
        println!("(PJRT artifacts missing; skipping kernel benches)");
    }

    // ---------- L3: diffusion stages on a big instance
    let mut inst = stencil::stencil_2d(96, 8, 8, Decomposition::Tiled); // 9216 objects
    stencil::inject_noise(&mut inst, 0.4, 2);
    let node_map = inst.node_mapping();
    let t = time_fn("stage1 comm_candidates (9216 obj, 64 PEs)", budget, || {
        neighbor::comm_candidates(&inst, &node_map).len()
    });
    report(&t, "");
    let cands = neighbor::comm_candidates(&inst, &node_map);
    let t = time_fn("stage1 handshake K=4", budget, || {
        neighbor::select_neighbors(&cands, 4, 32).max_degree()
    });
    report(&t, "");
    let neigh = neighbor::select_neighbors(&cands, 4, 32);
    let loads = inst.node_loads(&inst.mapping);
    let t = time_fn("stage2 virtual_balance", budget, || {
        virtual_lb::virtual_balance(&neigh, &loads, 0.05, 200).iterations
    });
    report(&t, "");
    let diff = Diffusion::communication(StrategyParams::default());
    use difflb::strategies::LoadBalancer;
    let t = time_fn("diffusion full rebalance", budget, || diff.rebalance(&inst).mapping[0]);
    report(&t, "");

    // ---------- baselines on the same instance
    for name in ["greedy-refine", "metis", "parmetis"] {
        let lb = make(name, StrategyParams::default())?;
        let t = time_fn(&format!("{name} rebalance"), budget, || lb.rebalance(&inst).mapping[0]);
        report(&t, "");
    }

    // ---------- metrics + plumbing
    let asg = diff.rebalance(&inst);
    let t = time_fn("evaluate_mapping", budget, || {
        evaluate_mapping(&inst, &asg.mapping).migrations
    });
    report(&t, "");
    let t = time_fn("instance .lbi serialize", budget, || inst.to_lbi().len());
    report(&t, "");

    // ---------- app iteration (binning + traffic)
    let cfg = PicConfig {
        grid: 1000,
        n_particles: 200_000,
        chares_x: 20,
        chares_y: 20,
        topo: Topology::flat(16),
        threads: 8,
        ..Default::default()
    };
    let mut app = PicApp::new(cfg, Backend::Native)?;
    let t = time_fn("pic app.step (200k particles)", budget, || {
        app.step().unwrap().crossers
    });
    report(&t, &format!("{:.1} Mparticles/s end-to-end", 200_000.0 / t.mean_s / 1e6));
    Ok(())
}
