//! §Perf — micro-benchmarks of every hot path in the stack, feeding
//! EXPERIMENTS.md §Perf: the native particle push (throughput), the
//! PJRT kernel path (dispatch + execute), the three diffusion stages,
//! the baselines, and the metrics/instance plumbing.
//!
//! Besides the human-readable report on stdout, every timed path is
//! recorded into `BENCH_hotpaths.json` (override the location with
//! `DIFFLB_BENCH_JSON`; shrink the per-path budget for smoke runs with
//! `DIFFLB_BENCH_BUDGET_MS`) so the perf trajectory is tracked
//! machine-readably from PR to PR.

use std::time::Duration;

use difflb::apps::pic::init::{initialize, InitMode};
use difflb::apps::pic::push::native_push;
use difflb::apps::pic::{Backend, PicApp, PicConfig};
use difflb::apps::stencil::{self, Decomposition, StencilSim};
use difflb::apps::{App, StepCtx};
use difflb::model::{evaluate_mapping, Topology};
use difflb::runtime::{Engine, Manifest, PicBatch};
use difflb::strategies::diffusion::{neighbor, virtual_lb, Diffusion};
use difflb::strategies::{make, LoadBalancer, StrategyParams};
use difflb::util::bench::{time_fn, JsonReport, Timing};

struct Report {
    json: JsonReport,
}

impl Report {
    fn record(&mut self, t: &Timing, throughput: Option<(&str, f64)>) {
        let extra = match throughput {
            Some((unit, v)) => format!("{v:.1} {unit}"),
            None => String::new(),
        };
        println!("{}  {extra}", t.report());
        self.json.add(t, throughput);
    }
}

fn main() -> anyhow::Result<()> {
    let budget_ms: u64 = std::env::var("DIFFLB_BENCH_BUDGET_MS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(400);
    let budget = Duration::from_millis(budget_ms);
    let mut rep = Report { json: JsonReport::new() };

    // ---------- L1/L2 surrogate + L3 compute: particle push
    let n = 65_536;
    let pop = initialize(InitMode::Geometric { rho: 0.9 }, n, 1000, 2, 1, 1.0, 1);
    let base = PicBatch { x: pop.x, y: pop.y, vx: pop.vx, vy: pop.vy, q: pop.q };
    for threads in [1usize, 4, 8] {
        let mut b = base.clone();
        let t = time_fn(&format!("native_push n={n} threads={threads}"), budget, || {
            native_push(&mut b, 1000.0, 1.0, threads);
            b.x[0]
        });
        let mps = n as f64 / t.mean_s / 1e6;
        rep.record(&t, Some(("Mparticles/s", mps)));
    }
    if let Ok(m) = Manifest::load_default() {
        match Engine::with_manifest(m) {
            Ok(engine) => {
                let mut b = base.clone();
                let t = time_fn(&format!("pjrt_push n={n}"), budget, || {
                    engine.pic_push(&mut b, 1000.0, 1.0).unwrap();
                    b.x[0]
                });
                let mps = n as f64 / t.mean_s / 1e6;
                rep.record(&t, Some(("Mparticles/s", mps)));
                // stencil artifact
                let grid: Vec<f64> = (0..256 * 256).map(|i| i as f64).collect();
                let t = time_fn("pjrt_stencil 256x256", budget, || {
                    engine.stencil_step(&grid, 256, 256, 0.2).unwrap()[0]
                });
                rep.record(&t, Some(("Mcell/s", 256.0 * 256.0 / t.mean_s / 1e6)));
            }
            Err(e) => println!("(PJRT engine unavailable: {e}; skipping kernel benches)"),
        }
    } else {
        println!("(PJRT artifacts missing; skipping kernel benches)");
    }

    // ---------- L3: diffusion stages on a big instance
    let mut inst = stencil::stencil_2d(96, 8, 8, Decomposition::Tiled); // 9216 objects
    stencil::inject_noise(&mut inst, 0.4, 2);
    let node_map = inst.node_mapping();
    let t = time_fn("stage1 comm_candidates (9216 obj, 64 PEs)", budget, || {
        neighbor::comm_candidates(&inst, &node_map).len()
    });
    rep.record(&t, None);
    let cands = neighbor::comm_candidates(&inst, &node_map);
    let t = time_fn("stage1 handshake K=4", budget, || {
        neighbor::select_neighbors(&cands, 4, 32).max_degree()
    });
    rep.record(&t, None);
    let neigh = neighbor::select_neighbors(&cands, 4, 32);
    let loads = inst.node_loads(&inst.mapping);
    let t = time_fn("stage2 virtual_balance", budget, || {
        virtual_lb::virtual_balance(&neigh, &loads, 0.05, 200).iterations
    });
    rep.record(&t, None);
    let diff = Diffusion::communication(StrategyParams::default());
    let t = time_fn("diffusion full rebalance", budget, || diff.rebalance(&inst).mapping[0]);
    let rps = 1.0 / t.mean_s;
    rep.record(&t, Some(("rebalances/s", rps)));

    // ---------- baselines on the same instance
    for name in ["greedy-refine", "metis", "parmetis"] {
        let lb = make(name, StrategyParams::default())?;
        let t = time_fn(&format!("{name} rebalance"), budget, || lb.rebalance(&inst).mapping[0]);
        rep.record(&t, None);
    }

    // ---------- metrics + plumbing
    let asg = diff.rebalance(&inst);
    let t = time_fn("evaluate_mapping", budget, || {
        evaluate_mapping(&inst, &asg.mapping).migrations
    });
    rep.record(&t, None);
    let t = time_fn("instance .lbi serialize", budget, || inst.to_lbi().len());
    rep.record(&t, None);
    let t = time_fn("instance .lbi encode (binary)", budget, || {
        difflb::model::encode_lbi(&inst).len()
    });
    rep.record(&t, None);
    let wire = difflb::model::encode_lbi(&inst);
    let t = time_fn("instance .lbi decode (binary)", budget, || {
        difflb::model::decode_lbi(&wire).unwrap().n_objects()
    });
    rep.record(&t, None);

    // ---------- incremental comm-graph refresh between LB rounds
    let mut sim = StencilSim::new(96, 8, 8, Decomposition::Tiled, 0.4, 3);
    let mut ctx = StepCtx::default();
    sim.step(&mut ctx)?;
    sim.refresh_graph(); // warm: structure established
    let t = time_fn("comm graph incremental refresh (9216 obj)", budget, || {
        ctx.moved.clear();
        sim.step(&mut ctx).unwrap();
        sim.refresh_graph()
    });
    rep.record(&t, None);

    // ---------- app iteration (binning + traffic)
    let cfg = PicConfig {
        grid: 1000,
        n_particles: 200_000,
        chares_x: 20,
        chares_y: 20,
        topo: Topology::flat(16),
        threads: 8,
        ..Default::default()
    };
    let mut app = PicApp::new(cfg, Backend::Native)?;
    let mut ctx = StepCtx::default();
    let t = time_fn("pic app.step (200k particles)", budget, || {
        ctx.moved.clear();
        app.step(&mut ctx).unwrap().events
    });
    let mps = 200_000.0 / t.mean_s / 1e6;
    rep.record(&t, Some(("Mparticles/s", mps)));

    // cargo bench runs this binary with cwd = the package root (rust/),
    // so the default anchors to the manifest dir's parent — the repo
    // root, where the tracked BENCH_hotpaths.json lives. An explicit
    // DIFFLB_BENCH_JSON is taken verbatim (pass an absolute path from
    // CI).
    let out = std::env::var("DIFFLB_BENCH_JSON").unwrap_or_else(|_| {
        format!("{}/../BENCH_hotpaths.json", env!("CARGO_MANIFEST_DIR"))
    });
    let label = format!(
        "perf_hotpaths budget={budget_ms}ms threads={}",
        std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1)
    );
    rep.json.write(&out, &label)?;
    println!("wrote {out} ({} paths)", rep.json.len());
    Ok(())
}
