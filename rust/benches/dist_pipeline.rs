//! Distributed-pipeline overhead: the same diffusion rebalance executed
//! sequentially (round-synchronous model) vs as real message-passing
//! protocols over the threaded simnet cluster, across node counts. The
//! two produce bit-identical assignments (asserted here per case, and
//! exhaustively in `tests/distributed.rs`); the delta is pure protocol
//! cost — thread spawns, message hops, reductions — i.e. what
//! "actually exchanging the messages" costs over modeling them.
//!
//! Run: `cargo bench --bench dist_pipeline`
//! (`DIFFLB_BENCH_BUDGET_MS` shrinks per-case budgets for smoke runs.)

use std::time::Duration;

use difflb::apps::stencil::{self, Decomposition};
use difflb::distributed::DistDiffusion;
use difflb::strategies::diffusion::{Diffusion, Variant};
use difflb::strategies::{LoadBalancer, StrategyParams};
use difflb::util::bench::{fmt_duration, time_fn, Table};

fn main() {
    let budget_ms: u64 = std::env::var("DIFFLB_BENCH_BUDGET_MS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(400);
    let budget = Duration::from_millis(budget_ms);

    let mut table = Table::new(
        "Distributed pipeline vs sequential model (48x48 stencil, diff-comm)",
        &["nodes", "sequential", "distributed", "protocol overhead"],
    );
    for &(px, py) in &[(2usize, 2usize), (4, 2), (4, 4)] {
        let n = px * py;
        let mut inst = stencil::stencil_2d(48, px, py, Decomposition::Tiled);
        stencil::inject_noise(&mut inst, 0.4, 0xBE | ((n as u64) << 8));
        let params = StrategyParams::default();
        let seq = Diffusion::communication(params);
        let dist = DistDiffusion::communication(params);
        assert_eq!(
            seq.rebalance(&inst).mapping,
            dist.rebalance(&inst).mapping,
            "bit-identity violated at {n} nodes"
        );
        let ts = time_fn(&format!("seq n={n}"), budget, || seq.rebalance(&inst));
        let td = time_fn(&format!("dist n={n}"), budget, || dist.rebalance(&inst));
        table.row(&[
            n.to_string(),
            fmt_duration(ts.mean_s),
            fmt_duration(td.mean_s),
            format!("{:.1}x", td.mean_s / ts.mean_s.max(1e-12)),
        ]);
    }
    // Coordinate variant at one size, for the record.
    {
        let mut inst = stencil::stencil_2d(48, 4, 2, Decomposition::Tiled);
        stencil::inject_noise(&mut inst, 0.4, 0xC0);
        let params = StrategyParams::default();
        let seq = Diffusion::coordinate(params);
        let dist = DistDiffusion::coordinate(params);
        assert_eq!(seq.rebalance(&inst).mapping, dist.rebalance(&inst).mapping);
        let ts = time_fn("seq coord n=8", budget, || seq.rebalance(&inst));
        let td = time_fn("dist coord n=8", budget, || dist.rebalance(&inst));
        table.row(&[
            "8 (coord)".to_string(),
            fmt_duration(ts.mean_s),
            fmt_duration(td.mean_s),
            format!("{:.1}x", td.mean_s / ts.mean_s.max(1e-12)),
        ]);
    }
    println!("{}", table.render());
}
