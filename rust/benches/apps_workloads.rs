//! Bench smoke for the App-trait workloads — tracks the two new
//! applications (streamline advection and the drifting hotspot) the
//! same way `perf_hotpaths` tracks the core paths, so BENCH numbers
//! start covering them: per-step throughput plus a short full run
//! through the generic driver under the diffusion strategy.
//!
//! Writes `BENCH_apps.json` (override with `DIFFLB_BENCH_JSON`; shrink
//! the per-path budget with `DIFFLB_BENCH_BUDGET_MS`).

use std::time::Duration;

use difflb::apps::advect::{Advect, AdvectConfig};
use difflb::apps::driver::{run_app, DriverConfig};
use difflb::apps::hotspot::{Hotspot, HotspotConfig};
use difflb::apps::{App, StepCtx};
use difflb::model::Topology;
use difflb::strategies::{make, StrategyParams};
use difflb::util::bench::{time_fn, JsonReport, Timing};

struct Report {
    json: JsonReport,
}

impl Report {
    fn record(&mut self, t: &Timing, throughput: Option<(&str, f64)>) {
        let extra = match throughput {
            Some((unit, v)) => format!("{v:.1} {unit}"),
            None => String::new(),
        };
        println!("{}  {extra}", t.report());
        self.json.add(t, throughput);
    }
}

fn main() -> anyhow::Result<()> {
    let budget_ms: u64 = std::env::var("DIFFLB_BENCH_BUDGET_MS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(400);
    let budget = Duration::from_millis(budget_ms);
    let mut rep = Report { json: JsonReport::new() };

    // ---------- advect: per-step integration throughput
    let n_particles = 100_000;
    let mut advect = Advect::new(AdvectConfig {
        n_particles,
        blocks_x: 16,
        blocks_y: 16,
        topo: Topology::flat(16),
        ..Default::default()
    })?;
    let mut ctx = StepCtx::default();
    let t = time_fn(&format!("advect app.step ({n_particles} particles)"), budget, || {
        ctx.moved.clear();
        advect.step(&mut ctx).unwrap().events
    });
    rep.record(&t, Some(("Mparticles/s", n_particles as f64 / t.mean_s / 1e6)));

    // ---------- advect: short full run through the generic driver
    let driver = DriverConfig { iters: 10, lb_period: 5, ..Default::default() };
    let t = time_fn("advect run_app 10 iters diff-comm (20k particles)", budget, || {
        let mut app = Advect::new(AdvectConfig {
            blocks_x: 8,
            blocks_y: 8,
            topo: Topology::flat(4),
            ..Default::default()
        })
        .unwrap();
        let strat = make("diff-comm", StrategyParams::default()).unwrap();
        run_app(&mut app, strat.as_ref(), &driver).unwrap().total_migrations
    });
    rep.record(&t, None);

    // ---------- hotspot: per-step load evaluation throughput
    let mut hotspot = Hotspot::new(HotspotConfig {
        nx: 64,
        ny: 64,
        topo: Topology::flat(16),
        ..Default::default()
    })?;
    let n_objs = 64 * 64;
    let mut ctx = StepCtx::default();
    let t = time_fn(&format!("hotspot app.step ({n_objs} objects)"), budget, || {
        ctx.moved.clear();
        hotspot.step(&mut ctx).unwrap().events
    });
    rep.record(&t, Some(("Mobj/s", n_objs as f64 / t.mean_s / 1e6)));

    // ---------- hotspot: short full run (the stale-assignment chaser)
    let t = time_fn("hotspot run_app 20 iters diff-comm (16x16)", budget, || {
        let mut app = Hotspot::new(HotspotConfig::default()).unwrap();
        let strat = make("diff-comm", StrategyParams::default()).unwrap();
        let driver = DriverConfig {
            iters: 20,
            lb_period: 5,
            deterministic_loads: true,
            ..Default::default()
        };
        run_app(&mut app, strat.as_ref(), &driver).unwrap().total_migrations
    });
    rep.record(&t, None);

    let out = std::env::var("DIFFLB_BENCH_JSON").unwrap_or_else(|_| {
        format!("{}/../BENCH_apps.json", env!("CARGO_MANIFEST_DIR"))
    });
    let label = format!(
        "apps_workloads budget={budget_ms}ms threads={}",
        std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1)
    );
    rep.json.write(&out, &label)?;
    println!("wrote {out} ({} paths)", rep.json.len());
    Ok(())
}
