//! Minimal, API-compatible stand-in for the `anyhow` crate.
//!
//! The build environment has no network access to crates.io, so this
//! vendored crate provides exactly the surface the workspace uses:
//! [`Error`], [`Result`], the [`Context`] extension trait for `Result`
//! and `Option`, and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! Differences from real anyhow (none of which the workspace relies
//! on): no backtrace capture, no downcasting, and source errors are
//! flattened to strings at construction time. Display `{:#}` renders
//! the full context chain joined by `: `, matching anyhow's alternate
//! formatting; `Debug` renders the anyhow-style `Caused by:` block so
//! `fn main() -> Result<()>` output stays readable.

use std::fmt;

/// `Result` specialized to [`Error`], like `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A type-erased error with a context chain (outermost first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Wrap a standard error, flattening its `source()` chain.
    pub fn new<E>(err: E) -> Error
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        let mut chain = vec![err.to_string()];
        let mut src = err.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }

    /// Create an error from a plain message.
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error { chain: vec![msg.to_string()] }
    }

    /// Attach an outer context message, like `anyhow::Error::context`.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The outermost (most recently attached) message.
    pub fn root_message(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                if self.chain.len() > 2 {
                    write!(f, "\n    {i}: {cause}")?;
                } else {
                    write!(f, "\n    {cause}")?;
                }
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        Error::new(err)
    }
}

/// Conversion into [`Error`] — implemented for all standard errors and
/// for [`Error`] itself, so [`Context`] methods work on `anyhow::Result`
/// the way they do upstream. (The two impls don't overlap because
/// `Error` deliberately does not implement `std::error::Error`.)
pub trait IntoError {
    fn into_error(self) -> Error;
}

impl<E> IntoError for E
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn into_error(self) -> Error {
        Error::new(self)
    }
}

impl IntoError for Error {
    fn into_error(self) -> Error {
        self
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: IntoError> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any `Display` value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(::std::concat!("condition failed: ", ::std::stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<u32> {
        s.parse::<u32>().with_context(|| format!("parsing '{s}'"))
    }

    #[test]
    fn context_chain_formats() {
        let err = parse("zzz").unwrap_err();
        assert_eq!(format!("{err}"), "parsing 'zzz'");
        let alt = format!("{err:#}");
        assert!(alt.starts_with("parsing 'zzz': "), "{alt}");
        assert!(format!("{err:?}").contains("Caused by"));
    }

    #[test]
    fn option_context_and_macros() {
        let none: Option<u32> = None;
        assert_eq!(format!("{}", none.context("missing").unwrap_err()), "missing");

        fn f(ok: bool) -> Result<u32> {
            ensure!(ok, "flag was {}", ok);
            bail!("unreachable {}", 1);
        }
        assert_eq!(format!("{}", f(false).unwrap_err()), "flag was false");
        assert_eq!(format!("{}", f(true).unwrap_err()), "unreachable 1");
    }

    #[test]
    fn context_on_anyhow_result() {
        let e: Result<u32> = Err(anyhow!("inner"));
        let e = e.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/here")?;
            Ok(s)
        }
        assert!(f().is_err());
    }
}
