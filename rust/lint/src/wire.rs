//! Wire-protocol rules: tag extraction, send/recv classification,
//! namespace collision, pairing, CTRL_NS confinement and
//! flag-independence of the message sequence.

use crate::lexer::{enclosing_call, find, is_word, word_occurrences};
use crate::{Emit, SourceFile};
use std::collections::{BTreeMap, BTreeSet};

/// One `const TAG_* / CTRL_NS : u32 = ...;` definition site.
pub struct Tag {
    pub name: String,
    pub value: u64,
    pub rel: String,
    pub line: usize,
}

/// Use counts of one tag across the wire layer.
#[derive(Default, Clone)]
pub struct Uses {
    pub sends: usize,
    pub recvs: usize,
    /// neither a direct send nor receive: a `tag_base` handed to a
    /// protocol helper, a mask computation, a re-export — treated as
    /// satisfying pairing (the helper sends and receives internally).
    pub other: usize,
}

/// `int(lit, 0)`-style literal parse (underscores already stripped).
fn parse_int(lit: &str) -> Option<u64> {
    let s = lit.trim();
    let (digits, radix) = if let Some(x) = s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
        (x, 16)
    } else if let Some(x) = s.strip_prefix("0o").or_else(|| s.strip_prefix("0O")) {
        (x, 8)
    } else if let Some(x) = s.strip_prefix("0b").or_else(|| s.strip_prefix("0B")) {
        (x, 2)
    } else {
        (s, 10)
    };
    u64::from_str_radix(digits, radix).ok()
}

/// Every `const TAG_*`/`const CTRL_NS` in the wire layer, in
/// (rel, line) order.
pub fn extract_tags(files: &[SourceFile]) -> Vec<Tag> {
    extract_consts(files, |name| name.starts_with("TAG_") || name == "CTRL_NS")
}

/// Every `const CT_*` control-message kind in the wire layer, in
/// (rel, line) order.
pub fn extract_ctrl_kinds(files: &[SourceFile]) -> Vec<Tag> {
    extract_consts(files, |name| name.starts_with("CT_"))
}

fn extract_consts(files: &[SourceFile], want: fn(&str) -> bool) -> Vec<Tag> {
    let mut tags = Vec::new();
    for f in files {
        if !crate::is_wire_file(&f.rel) {
            continue;
        }
        let text = &f.text;
        for pos in word_occurrences(text, b"const") {
            let mut i = pos + b"const".len();
            while i < text.len() && (text[i] == b' ' || text[i] == b'\t') {
                i += 1;
            }
            let mut j = i;
            while j < text.len() && is_word(text[j]) {
                j += 1;
            }
            let name = String::from_utf8_lossy(&text[i..j]).into_owned();
            if !want(&name) {
                continue;
            }
            let rest = &text[j..(j + 80).min(text.len())];
            let mut k = 0usize;
            while k < rest.len() && (rest[k] == b' ' || rest[k] == b'\t') {
                k += 1;
            }
            if k >= rest.len() || rest[k] != b':' {
                continue;
            }
            let (Some(eq), Some(semi)) = (find(rest, b"=", k), find(rest, b";", k)) else {
                continue;
            };
            if eq > semi {
                continue;
            }
            let lit: String = String::from_utf8_lossy(&rest[eq + 1..semi])
                .chars()
                .filter(|&c| c != '_')
                .collect();
            let Some(value) = parse_int(&lit) else {
                continue;
            };
            tags.push(Tag { name, value, rel: f.rel.clone(), line: f.line(pos) });
        }
    }
    tags
}

/// Classify every non-definition occurrence of each tag by the call
/// it sits in: `send(..)` / `recv_tagged(..)|barrier(..)` / other.
pub fn classify_uses(files: &[SourceFile], tags: &[Tag]) -> BTreeMap<String, Uses> {
    let defs: BTreeSet<(&str, usize)> =
        tags.iter().map(|t| (t.rel.as_str(), t.line)).collect();
    let mut counts: BTreeMap<String, Uses> =
        tags.iter().map(|t| (t.name.clone(), Uses::default())).collect();
    for f in files {
        if !crate::is_wire_file(&f.rel) {
            continue;
        }
        for t in tags {
            let c = counts.get_mut(&t.name).expect("counts cover every tag");
            for pos in word_occurrences(&f.text, t.name.as_bytes()) {
                if defs.contains(&(f.rel.as_str(), f.line(pos))) {
                    continue;
                }
                match enclosing_call(&f.text, pos) {
                    b"send" => c.sends += 1,
                    b"recv_tagged" | b"barrier" => c.recvs += 1,
                    _ => c.other += 1,
                }
            }
        }
    }
    counts
}

pub fn wire_findings(
    files: &[SourceFile],
    tags: &[Tag],
    counts: &BTreeMap<String, Uses>,
    emit: &mut Emit<'_>,
) {
    // ---- namespace layout: low 24 bits clear, top byte unique.
    let mut seen_ns: BTreeMap<u64, &str> = BTreeMap::new();
    for t in tags {
        if t.value & 0x00FF_FFFF != 0 {
            emit.finding(
                &t.rel,
                t.line,
                "tag-collision",
                format!(
                    "tag namespace constant {} = 0x{:08x} sets low-24 bits \
                     (namespaces are the top byte)",
                    t.name, t.value
                ),
            );
        }
        let ns = t.value >> 24;
        if let Some(first) = seen_ns.get(&ns) {
            emit.finding(
                &t.rel,
                t.line,
                "tag-collision",
                format!("tag {} shares namespace byte 0x{ns:02x} with {first}", t.name),
            );
        } else {
            seen_ns.insert(ns, &t.name);
        }
    }
    // ---- ctrl-kind budget: control kinds ride in the low 4 bits of a
    // CTRL_NS tag (map tags pack the LB round from bit 4 up, so a kind
    // at 0x10 or above aliases another kind at a shifted round).
    let kinds = extract_ctrl_kinds(files);
    let mut seen_kind: BTreeMap<u64, &str> = BTreeMap::new();
    for k in &kinds {
        if k.value >= 0x10 {
            emit.finding(
                &k.rel,
                k.line,
                "ctrl-kind-budget",
                format!(
                    "ctrl kind {} = 0x{:x} overflows the 4-bit kind field \
                     (map tags pack the LB round from bit 4 up)",
                    k.name, k.value
                ),
            );
        }
        if let Some(first) = seen_kind.get(&k.value) {
            emit.finding(
                &k.rel,
                k.line,
                "ctrl-kind-budget",
                format!("ctrl kind {} reuses value 0x{:x} of {first}", k.name, k.value),
            );
        } else {
            seen_kind.insert(k.value, &k.name);
        }
    }
    // ---- pairing: every data tag both sent and received somewhere
    // (helper indirection counts as both).
    for t in tags {
        if t.name == "CTRL_NS" {
            continue;
        }
        let c = &counts[&t.name];
        let total = c.sends + c.recvs + c.other;
        if total == 0 {
            emit.finding(&t.rel, t.line, "tag-unpaired", format!("tag {} is never used", t.name));
        } else if c.sends > 0 && c.recvs == 0 && c.other == 0 {
            emit.finding(
                &t.rel,
                t.line,
                "tag-unpaired",
                format!("tag {} is sent but never received", t.name),
            );
        } else if c.recvs > 0 && c.sends == 0 && c.other == 0 {
            emit.finding(
                &t.rel,
                t.line,
                "tag-unpaired",
                format!("tag {} is received but never sent", t.name),
            );
        }
    }

    for f in files {
        if !crate::is_wire_file(&f.rel) {
            continue;
        }
        // ---- CTRL_NS confinement to the epoch layer.
        if !crate::CTRL_NS_ALLOWED.contains(&f.rel.as_str()) {
            for pos in word_occurrences(&f.text, b"CTRL_NS") {
                emit.finding(
                    &f.rel,
                    f.line(pos),
                    "ctrl-ns",
                    "CTRL_NS outside the epoch layer \
                     (allowed: simnet/network.rs, distributed/epoch.rs)"
                        .to_string(),
                );
            }
        }
        // ---- flag-independence: no comm call lexically inside an
        // `if ...tracing_enabled()/metrics_enabled()...` block.
        let text = &f.text;
        for pos in word_occurrences(text, b"if") {
            let mut brace = None;
            let mut depth = 0i64;
            let mut i = pos + 2;
            while i < text.len() && i < pos + 300 {
                match text[i] {
                    b'(' => depth += 1,
                    b')' => depth -= 1,
                    b'{' if depth == 0 => {
                        brace = Some(i);
                        break;
                    }
                    b';' => break,
                    _ => {}
                }
                i += 1;
            }
            let Some(brace) = brace else {
                continue;
            };
            let cond = &text[pos..brace];
            if find(cond, b"tracing_enabled", 0).is_none()
                && find(cond, b"metrics_enabled", 0).is_none()
            {
                continue;
            }
            let mut depth = 0i64;
            let mut end = brace;
            while end < text.len() {
                if text[end] == b'{' {
                    depth += 1;
                } else if text[end] == b'}' {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                end += 1;
            }
            let block = &text[brace..end.min(text.len())];
            const CALLS: [&[u8]; 3] = [b".send(", b".recv_tagged(", b".barrier("];
            for call in CALLS {
                let mut k = find(block, call, 0);
                while let Some(p) = k {
                    emit.finding(
                        &f.rel,
                        f.line(brace + p),
                        "flag-guarded-send",
                        "comm call inside a telemetry-flag conditional \
                         (wire sequence must not depend on obs flags)"
                            .to_string(),
                    );
                    k = find(block, call, p + 1);
                }
            }
        }
    }
}
