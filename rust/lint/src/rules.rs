//! Determinism rules: container iteration order, float comparison
//! totality, wall-clock reads, `static mut`, Comm-result unwraps, and
//! seed-era by-node indexes in the SoA hot paths.

use crate::lexer::{chained_method, is_word, match_paren, word_occurrences};
use crate::{Emit, SourceFile};

const UNWRAPPERS: [&[u8]; 4] = [b"unwrap", b"unwrap_or", b"unwrap_or_else", b"expect"];

pub fn determinism_findings(f: &SourceFile, emit: &mut Emit<'_>) {
    let text = &f.text;

    // ---- hash-map: iteration order must be deterministic in any
    // module whose output feeds an assignment decision. One finding
    // per line, however many mentions the line holds.
    if crate::hash_map_scoped(&f.rel) {
        let mut lines_hit: Vec<usize> = Vec::new();
        const HASHES: [&[u8]; 2] = [b"HashMap", b"HashSet"];
        for word in HASHES {
            for pos in word_occurrences(text, word) {
                lines_hit.push(f.line(pos));
            }
        }
        lines_hit.sort_unstable();
        lines_hit.dedup();
        for ln in lines_hit {
            emit.finding(
                &f.rel,
                ln,
                "hash-map",
                "HashMap/HashSet in a decision-path module; \
                 use BTreeMap/BTreeSet or a sorted drain"
                    .to_string(),
            );
        }
    }

    // ---- partial-cmp: .partial_cmp(..) chained into an unwrap is a
    // NaN landmine and not a total order; total_cmp is both.
    for pos in word_occurrences(text, b"partial_cmp") {
        if pos == 0 || text[pos - 1] != b'.' {
            continue;
        }
        let open_pos = pos + b"partial_cmp".len();
        if open_pos >= text.len() || text[open_pos] != b'(' {
            continue;
        }
        let Some(close) = match_paren(text, open_pos) else {
            continue;
        };
        if UNWRAPPERS.contains(&chained_method(text, close + 1)) {
            emit.finding(
                &f.rel,
                f.line(pos),
                "partial-cmp",
                "partial_cmp().unwrap() on floats; use total_cmp".to_string(),
            );
        }
    }

    // ---- wall-clock: real time must never feed a decision; reads
    // outside obs/ need an annotation stating they are measurement.
    if !crate::wall_clock_allowed(&f.rel) {
        const CLOCKS: [&[u8]; 2] = [b"Instant::now", b"SystemTime::now"];
        for pat in CLOCKS {
            let head_len = pat.iter().position(|&b| b == b':').expect("pattern has ::");
            for pos in word_occurrences(text, &pat[..head_len]) {
                if text[pos..].starts_with(pat) {
                    emit.finding(
                        &f.rel,
                        f.line(pos),
                        "wall-clock",
                        "wall-clock read outside obs/; \
                         annotate if this is measurement, not decision input"
                            .to_string(),
                    );
                }
            }
        }
    }

    // ---- static-mut: banned outright.
    for pos in word_occurrences(text, b"static") {
        let rest = &text[pos + b"static".len()..];
        let mut k = 0usize;
        while k < rest.len() && (rest[k] == b' ' || rest[k] == b'\t') {
            k += 1;
        }
        if rest[k..].starts_with(b"mut") && (k + 3 >= rest.len() || !is_word(rest[k + 3])) {
            emit.finding(
                &f.rel,
                f.line(pos),
                "static-mut",
                "static mut is a data race waiting to happen; \
                 use atomics or OnceLock"
                    .to_string(),
            );
        }
    }

    // ---- soa-index: the stage-3 / §III-D hot paths walk LbScratch's
    // sorted-by-node SoA slices; reintroducing the seed's per-node
    // object index (one heap-allocated row per node, rebuilt by a full
    // scan) undoes the cache contiguity the selection kernels rely on.
    if crate::soa_scoped(&f.rel) {
        let mut lines_hit: Vec<usize> = Vec::new();
        const LEGACY_INDEX: [&[u8]; 2] = [b"by_node", b"node_objects"];
        for word in LEGACY_INDEX {
            for pos in word_occurrences(text, word) {
                lines_hit.push(f.line(pos));
            }
        }
        lines_hit.sort_unstable();
        lines_hit.dedup();
        for ln in lines_hit {
            emit.finding(
                &f.rel,
                ln,
                "soa-index",
                "seed-era by-node object index in a stage-3 hot path; \
                 walk LbScratch's sorted-by-node SoA slices"
                    .to_string(),
            );
        }
    }

    // ---- comm-unwrap: Comm results in distributed/ must propagate.
    if f.rel.starts_with("distributed/") {
        const COMM_RECVS: [&[u8]; 2] = [b"recv_tagged", b"barrier"];
        for word in COMM_RECVS {
            for pos in word_occurrences(text, word) {
                if pos == 0 || text[pos - 1] != b'.' {
                    continue;
                }
                let open_pos = pos + word.len();
                if open_pos >= text.len() || text[open_pos] != b'(' {
                    continue;
                }
                let Some(close) = match_paren(text, open_pos) else {
                    continue;
                };
                if UNWRAPPERS.contains(&chained_method(text, close + 1)) {
                    emit.finding(
                        &f.rel,
                        f.line(pos),
                        "comm-unwrap",
                        "Comm result unwrapped; propagate CommError \
                         so recovery stays reachable"
                            .to_string(),
                    );
                }
            }
        }
    }
}
