//! CLI: `difflb-lint [--tags] [root]` (default root: rust/src).
//!
//! Without `--tags`, prints findings one per line and exits 1 if any
//! survive the allowlist. With `--tags`, prints the wire-protocol tag
//! table for cross-validation against `tools/lint_report.py --tags`.

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    let tags_mode = args.iter().any(|a| a == "--tags");
    args.retain(|a| a != "--tags");
    let root = PathBuf::from(args.first().map_or("rust/src", String::as_str));

    let files = match difflb_lint::load_files(&root) {
        Ok(files) => files,
        Err(e) => {
            eprintln!("difflb-lint: cannot read {}: {e}", root.display());
            return ExitCode::from(2);
        }
    };

    if tags_mode {
        print!("{}", difflb_lint::tag_table(&files));
        return ExitCode::SUCCESS;
    }

    let findings = difflb_lint::analyze(&files);
    for f in &findings {
        println!("{f}");
    }
    eprintln!("{} finding(s) across {} file(s)", findings.len(), files.len());
    if findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
