//! difflb-lint: project-specific static analysis for the difflb
//! workspace — wire-protocol invariants (tag namespaces, send/recv
//! pairing, CTRL_NS confinement, flag-independence of the message
//! sequence) and determinism invariants (no HashMap/HashSet in
//! decision paths, no `partial_cmp().unwrap()`, no wall-clock reads
//! outside obs/, no `static mut`, no unwrapped Comm results in
//! distributed/, no seed-era by-node object indexes in the SoA
//! stage-3 hot paths).
//!
//! Rules run over lexed source text (comments/strings blanked,
//! `#[cfg(test)]` items removed) — see [`lexer`]. Findings are
//! suppressed by an inline annotation on the finding's line or the
//! line directly above it:
//!
//! ```text
//! // difflb-lint: allow(<rule>): <reason>
//! ```
//!
//! `tools/lint_report.py` is a regex/lexer twin of this crate for
//! in-container use; CI cross-validates the two by diffing their
//! `--tags` output and requiring zero findings from both.

pub mod lexer;
mod rules;
mod wire;

use lexer::{line_of, line_starts_of, Allows};
use std::fmt;
use std::path::Path;

pub use wire::{classify_uses, extract_tags, Tag, Uses};

/// One rule violation at a source location.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct Finding {
    pub rel: String,
    pub line: usize,
    pub rule: String,
    pub msg: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.rel, self.line, self.rule, self.msg)
    }
}

/// One lexed source file: blanked text, allow annotations, line table.
pub struct SourceFile {
    pub rel: String,
    pub text: Vec<u8>,
    pub allows: Allows,
    starts: Vec<usize>,
}

impl SourceFile {
    pub fn parse(rel: String, src: &[u8]) -> Self {
        let (cleaned, allows) = lexer::clean_source(src);
        let text = lexer::blank_cfg_test(&cleaned);
        let starts = line_starts_of(&text);
        SourceFile { rel, text, allows, starts }
    }

    /// 1-based line of byte offset `pos`.
    pub fn line(&self, pos: usize) -> usize {
        line_of(pos, &self.starts)
    }
}

/// Finding sink that applies each file's allow annotations.
pub struct Emit<'a> {
    files: &'a [SourceFile],
    pub findings: Vec<Finding>,
}

impl Emit<'_> {
    pub fn finding(&mut self, rel: &str, line: usize, rule: &str, msg: String) {
        let f = self.files.iter().find(|f| f.rel == rel).expect("finding in a loaded file");
        if f.allows.get(&line).is_some_and(|rules| rules.contains(rule)) {
            return;
        }
        self.findings.push(Finding { rel: rel.to_string(), line, rule: rule.to_string(), msg });
    }
}

// ---- rule scoping by repo-relative path (relative to the scan root,
// which is rust/src in CI).

/// Wire-protocol rules run over the message-passing layers only.
pub fn is_wire_file(rel: &str) -> bool {
    rel.starts_with("distributed/") || rel.starts_with("simnet/")
}

/// Decision-path modules where container iteration order reaches an
/// assignment decision.
pub fn hash_map_scoped(rel: &str) -> bool {
    rel.starts_with("strategies/") || rel.starts_with("model/") || rel.starts_with("distributed/")
}

/// Telemetry and harness code may read real time freely.
pub fn wall_clock_allowed(rel: &str) -> bool {
    rel.starts_with("obs/") || rel == "util/bench.rs" || rel == "util/logging.rs"
}

/// Stage-3 / §III-D hot paths that must iterate the scratch's
/// sorted-by-node SoA index, never a rebuilt per-node `Vec<Vec<u32>>`
/// (`by_node`) or a per-node full-object scan (`node_objects`).
pub fn soa_scoped(rel: &str) -> bool {
    matches!(
        rel,
        "strategies/diffusion/object_selection.rs"
            | "strategies/diffusion/hierarchical.rs"
            | "distributed/stage3.rs"
    )
}

/// The only files allowed to mention CTRL_NS: its definition and the
/// epoch control plane.
pub const CTRL_NS_ALLOWED: [&str; 2] = ["simnet/network.rs", "distributed/epoch.rs"];

/// Load every `.rs` file under `root`, lexed, sorted by relative path.
pub fn load_files(root: &Path) -> std::io::Result<Vec<SourceFile>> {
    fn walk(dir: &Path, root: &Path, rels: &mut Vec<String>) -> std::io::Result<()> {
        for entry in std::fs::read_dir(dir)? {
            let path = entry?.path();
            if path.is_dir() {
                walk(&path, root, rels)?;
            } else if path.extension().is_some_and(|x| x == "rs") {
                let rel = path
                    .strip_prefix(root)
                    .expect("walk stays under root")
                    .to_string_lossy()
                    .replace('\\', "/");
                rels.push(rel);
            }
        }
        Ok(())
    }
    let mut rels = Vec::new();
    walk(root, root, &mut rels)?;
    rels.sort();
    rels.into_iter()
        .map(|rel| {
            let src = std::fs::read(root.join(&rel))?;
            Ok(SourceFile::parse(rel, &src))
        })
        .collect()
}

/// Run every rule over `files`; findings sorted by (rel, line, rule).
pub fn analyze(files: &[SourceFile]) -> Vec<Finding> {
    let tags = wire::extract_tags(files);
    let counts = wire::classify_uses(files, &tags);
    let mut emit = Emit { files, findings: Vec::new() };
    wire::wire_findings(files, &tags, &counts, &mut emit);
    for f in files {
        rules::determinism_findings(f, &mut emit);
    }
    let mut findings = emit.findings;
    findings.sort();
    findings
}

/// The wire-protocol tag table, one line per tag sorted by
/// (value, name) — byte-identical to `tools/lint_report.py --tags`.
pub fn tag_table(files: &[SourceFile]) -> String {
    use fmt::Write as _;
    let tags = wire::extract_tags(files);
    let counts = wire::classify_uses(files, &tags);
    let mut sorted: Vec<&Tag> = tags.iter().collect();
    sorted.sort_by(|a, b| a.value.cmp(&b.value).then_with(|| a.name.cmp(&b.name)));
    let mut out = String::new();
    for t in sorted {
        let c = &counts[&t.name];
        let _ = writeln!(
            out,
            "{} 0x{:08x} {} sends={} recvs={} other={}",
            t.name, t.value, t.rel, c.sends, c.recvs, c.other
        );
    }
    out
}
