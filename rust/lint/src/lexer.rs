//! A minimal Rust lexer over raw bytes: blank comments, strings and
//! char literals (newlines preserved, so byte offsets keep their line
//! numbers), collect `difflb-lint: allow(<rule>)` annotations from
//! line comments, and blank `#[cfg(test)]` items. No syn — the build
//! environment is offline and the rules below only need token-free
//! text plus word-boundary search.
//!
//! `tools/lint_report.py` is the byte-for-byte twin of this module;
//! CI diffs the two implementations' `--tags` output. Any change here
//! must land in the twin too.

use std::collections::{BTreeMap, BTreeSet};

/// Allow-annotations: line number -> rules suppressed on that line.
/// An annotation at line L covers findings on L and L+1, so both a
/// trailing comment and a comment on the line above work.
pub type Allows = BTreeMap<usize, BTreeSet<String>>;

pub const ALLOW_MARK: &[u8] = b"difflb-lint: allow(";

pub fn is_word(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// First occurrence of `needle` in `hay` at or after `from`.
pub fn find(hay: &[u8], needle: &[u8], from: usize) -> Option<usize> {
    if needle.is_empty() || from >= hay.len() {
        return None;
    }
    hay[from..]
        .windows(needle.len())
        .position(|w| w == needle)
        .map(|p| p + from)
}

fn blank(out: &mut [u8], start: usize, end: usize) {
    let end = end.min(out.len());
    if start >= end {
        return;
    }
    for b in &mut out[start..end] {
        if *b != b'\n' {
            *b = b' ';
        }
    }
}

fn note_allow(text: &[u8], at_line: usize, allows: &mut Allows) {
    let mut k = find(text, ALLOW_MARK, 0);
    while let Some(p) = k {
        let start = p + ALLOW_MARK.len();
        let Some(end) = find(text, b")", start) else {
            break;
        };
        let rule = String::from_utf8_lossy(&text[start..end]).trim().to_string();
        for ln in [at_line, at_line + 1] {
            allows.entry(ln).or_default().insert(rule.clone());
        }
        k = find(text, ALLOW_MARK, end);
    }
}

/// Blank comments, strings and char literals, collecting allow
/// annotations. Newlines inside blanked regions are preserved.
pub fn clean_source(src: &[u8]) -> (Vec<u8>, Allows) {
    let n = src.len();
    let mut out = src.to_vec();
    let mut allows = Allows::new();
    let mut line = 1usize;
    let mut i = 0usize;

    while i < n {
        let c = src[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        // line comment (the only place allow annotations live)
        if c == b'/' && i + 1 < n && src[i + 1] == b'/' {
            let mut j = i;
            while j < n && src[j] != b'\n' {
                j += 1;
            }
            note_allow(&src[i..j], line, &mut allows);
            blank(&mut out, i, j);
            i = j;
            continue;
        }
        // block comment, nested
        if c == b'/' && i + 1 < n && src[i + 1] == b'*' {
            let mut depth = 1u32;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if src[j] == b'\n' {
                    line += 1;
                }
                if src[j..].starts_with(b"/*") {
                    depth += 1;
                    j += 2;
                } else if src[j..].starts_with(b"*/") {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            blank(&mut out, i, j);
            i = j;
            continue;
        }
        // raw strings: r"..." / r#"..."# (optional b prefix)
        if c == b'r' || c == b'b' {
            let mut j = i;
            if src[j] == b'b' {
                j += 1;
            }
            if j < n && src[j] == b'r' {
                j += 1;
                let mut hashes = 0usize;
                while j < n && src[j] == b'#' {
                    hashes += 1;
                    j += 1;
                }
                if j < n && src[j] == b'"' {
                    let mut closer = vec![b'"'];
                    closer.resize(1 + hashes, b'#');
                    let end = match find(src, &closer, j + 1) {
                        Some(e) => e + closer.len(),
                        None => n,
                    };
                    line += src[i..end].iter().filter(|&&b| b == b'\n').count();
                    blank(&mut out, i, end);
                    i = end;
                    continue;
                }
            }
        }
        // plain / byte strings
        if c == b'"' || (c == b'b' && i + 1 < n && src[i + 1] == b'"') {
            let mut j = i + if c == b'b' { 2 } else { 1 };
            while j < n {
                if src[j] == b'\\' {
                    // escape: count a line-continuation's newline too
                    if j + 1 < n && src[j + 1] == b'\n' {
                        line += 1;
                    }
                    j += 2;
                    continue;
                }
                if src[j] == b'\n' {
                    line += 1;
                }
                if src[j] == b'"' {
                    j += 1;
                    break;
                }
                j += 1;
            }
            blank(&mut out, i, j);
            i = j;
            continue;
        }
        // char literal vs lifetime: 'x' or '\x' is a literal
        if c == b'\'' {
            if i + 1 < n && src[i + 1] == b'\\' {
                let mut j = i + 2;
                while j < n && src[j] != b'\'' {
                    j += 1;
                }
                j += 1;
                blank(&mut out, i, j);
                i = j;
                continue;
            }
            if i + 2 < n && src[i + 2] == b'\'' {
                blank(&mut out, i, i + 3);
                i += 3;
                continue;
            }
            i += 1;
            continue;
        }
        i += 1;
    }
    (out, allows)
}

/// Blank `#[cfg(test)]` items (the attribute through the following
/// brace-matched block): test modules must not trip wire or
/// determinism rules.
pub fn blank_cfg_test(cleaned: &[u8]) -> Vec<u8> {
    let mut out = cleaned.to_vec();
    let attr: &[u8] = b"#[cfg(test)]";
    let mut pos = 0usize;
    while let Some(start) = find(cleaned, attr, pos) {
        let Some(brace) = find(cleaned, b"{", start) else {
            break;
        };
        let mut depth = 0i64;
        let mut end = brace;
        while end < cleaned.len() {
            if cleaned[end] == b'{' {
                depth += 1;
            } else if cleaned[end] == b'}' {
                depth -= 1;
                if depth == 0 {
                    end += 1;
                    break;
                }
            }
            end += 1;
        }
        blank(&mut out, start, end);
        pos = end;
    }
    out
}

/// Byte offsets where each line starts, for offset -> line lookup.
pub fn line_starts_of(text: &[u8]) -> Vec<usize> {
    let mut starts = vec![0];
    for (i, &c) in text.iter().enumerate() {
        if c == b'\n' {
            starts.push(i + 1);
        }
    }
    starts
}

/// 1-based line number of byte offset `pos`.
pub fn line_of(pos: usize, starts: &[usize]) -> usize {
    starts.partition_point(|&s| s <= pos)
}

/// Word-boundary occurrences of `word` in `text`.
pub fn word_occurrences(text: &[u8], word: &[u8]) -> Vec<usize> {
    let mut out = Vec::new();
    let mut from = 0usize;
    while let Some(i) = find(text, word, from) {
        let before_ok = i == 0 || !is_word(text[i - 1]);
        let after = i + word.len();
        let after_ok = after >= text.len() || !is_word(text[after]);
        if before_ok && after_ok {
            out.push(i);
        }
        from = i + 1;
    }
    out
}

/// Identifier of the innermost call whose argument list contains
/// `pos`, or empty if the occurrence is not inside a call. Bounded
/// backward scan: statements here are short, 600 bytes is plenty.
pub fn enclosing_call(text: &[u8], pos: usize) -> &[u8] {
    let mut depth = 0i64;
    let mut steps = 0usize;
    let mut i = pos as i64 - 1;
    while i >= 0 && steps < 600 {
        let c = text[i as usize];
        if c == b')' {
            depth += 1;
        } else if c == b'(' {
            if depth == 0 {
                let j = i - 1;
                let mut k = j;
                while k >= 0 && is_word(text[k as usize]) {
                    k -= 1;
                }
                return &text[(k + 1) as usize..(j + 1) as usize];
            }
            depth -= 1;
        } else if (c == b';' || c == b'{' || c == b'}') && depth == 0 {
            return b"";
        }
        i -= 1;
        steps += 1;
    }
    b""
}

/// Matching `)` for the `(` at `open_pos`, or None.
pub fn match_paren(text: &[u8], open_pos: usize) -> Option<usize> {
    let mut depth = 0i64;
    let mut i = open_pos;
    while i < text.len() {
        if text[i] == b'(' {
            depth += 1;
        } else if text[i] == b')' {
            depth -= 1;
            if depth == 0 {
                return Some(i);
            }
        }
        i += 1;
    }
    None
}

/// Skip whitespace after `after`; if the next token is `.method`,
/// return the method name, else empty.
pub fn chained_method(text: &[u8], after: usize) -> &[u8] {
    let mut i = after;
    while i < text.len() && (text[i] == b' ' || text[i] == b'\t' || text[i] == b'\n') {
        i += 1;
    }
    if i >= text.len() || text[i] != b'.' {
        return b"";
    }
    i += 1;
    let j = i;
    let mut k = j;
    while k < text.len() && is_word(text[k]) {
        k += 1;
    }
    &text[j..k]
}
