//! Bad wire-protocol fixture: every wire rule fires at least once.
//! Not compiled — scanned by rust/lint/tests/fixtures.rs, which pins
//! the exact findings (rule, line) this file must produce.

use std::collections::HashMap;

pub const TAG_A: u32 = 0x0100_0000;
pub const TAG_B: u32 = 0x0100_0000;
pub const TAG_LOW: u32 = 0x0200_0001;
pub const TAG_ONEWAY: u32 = 0x0300_0000;
pub const TAG_ORPHAN: u32 = 0x0400_0000;
pub const TAG_DEAD: u32 = 0x0500_0000;
pub const CTRL_NS: u32 = 0x7F00_0000;

pub fn exchange(comm: &mut Comm, buf: Vec<u8>) {
    comm.send(1, TAG_A, buf.clone());
    let _pong = comm.recv_tagged(TAG_A, 1, TIMEOUT);
    comm.send(1, TAG_ONEWAY, buf.clone());
    let _one = comm.recv_tagged(TAG_ORPHAN, 1, TIMEOUT).unwrap();
    if crate::obs::tracing_enabled() {
        comm.send(1, TAG_B, buf);
    }
    let _routing: HashMap<u32, u32> = HashMap::new();
}

pub const CT_OK: u32 = 1;
pub const CT_WIDE: u32 = 0x10;
pub const CT_DUP: u32 = 1;
