//! Bad determinism fixture for the model/ scope.

use std::collections::HashMap;

pub fn heaviest(edges: &HashMap<(u32, u32), f64>) -> Option<(u32, u32)> {
    edges
        .iter()
        .max_by(|a, b| a.1.partial_cmp(b.1).expect("weights are not NaN"))
        .map(|(&k, _)| k)
}
