//! Bad determinism fixture outside the hash-map scope: hash-map must
//! NOT fire here (util/ is not a decision-path module), but
//! partial-cmp and wall-clock are repo-wide.

use std::collections::HashMap;

pub fn median(v: &mut Vec<f64>) -> f64 {
    let _epoch = std::time::SystemTime::now();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let _cache: HashMap<u64, f64> = HashMap::new();
    v[v.len() / 2]
}
