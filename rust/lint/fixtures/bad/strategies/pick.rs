//! Bad determinism fixture for the strategies/ scope.

use std::collections::HashSet;

static mut COUNTER: u64 = 0;

pub fn pick(xs: &mut Vec<(u32, f64)>) -> HashSet<u32> {
    let _t = std::time::Instant::now();
    xs.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    let mut out = HashSet::new();
    for &(c, _) in xs.iter() {
        out.insert(c);
    }
    out
}
