//! Bad SoA fixture: the seed's per-node object index in a stage-3 path.

pub struct Scratch {
    pub by_node: Vec<Vec<u32>>,
}

pub fn pool_for(s: &Scratch, node_objects: &[Vec<u32>], i: usize) -> Vec<u32> {
    let mut pool = s.by_node[i].clone();
    pool.extend(node_objects[i].iter().copied());
    pool
}
