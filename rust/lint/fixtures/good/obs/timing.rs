//! Good scoping fixture: obs/ may read real time without annotation.

pub fn stamp() -> std::time::Instant {
    std::time::Instant::now()
}
