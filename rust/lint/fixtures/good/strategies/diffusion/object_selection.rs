//! Good SoA fixture: contiguous sorted-by-node slices, plus both allow
//! annotation placements for a deliberate legacy-index mention.

pub struct Scratch {
    pub soa_offsets: Vec<u32>,
    pub soa_objs: Vec<u32>,
}

impl Scratch {
    pub fn node_slice(&self, i: usize) -> &[u32] {
        &self.soa_objs[self.soa_offsets[i] as usize..self.soa_offsets[i + 1] as usize]
    }
}

// difflb-lint: allow(soa-index): fixture proving line-above annotations suppress
pub fn legacy_rows(by_node: &[Vec<u32>]) -> usize {
    by_node.len() // difflb-lint: allow(soa-index): fixture proving trailing annotations suppress
}
