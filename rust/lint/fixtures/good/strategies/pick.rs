//! Good determinism fixture: BTreeMap, total_cmp, and both allow
//! annotation placements (line above, trailing).

use std::collections::BTreeMap;

pub fn pick(xs: &mut [(u32, f64)]) -> BTreeMap<u32, f64> {
    xs.sort_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)));
    // difflb-lint: allow(wall-clock): fixture proving line-above annotations suppress
    let _t = std::time::Instant::now();
    let _scratch: HashSet<u32> = HashSet::new(); // difflb-lint: allow(hash-map): fixture proving trailing annotations suppress
    let mut out = BTreeMap::new();
    for &(c, w) in xs.iter() {
        *out.entry(c).or_insert(0.0) += w;
    }
    out
}
