//! Good lexer fixture: CTRL_NS in its allowed file, plus comment /
//! string / char-literal content that must never leak into the rules.

pub const CTRL_NS: u32 = 0x7F00_0000;

pub fn is_ctrl_tag(tag: u32) -> bool {
    tag & CTRL_NS == CTRL_NS
}

/* block comments may mention HashMap, static mut,
   Instant::now and partial_cmp().unwrap() freely */
pub fn banner<'a>(name: &'a str) -> String {
    let quote = '"';
    let escaped = '\'';
    let raw = r#"strings may mention .partial_cmp(x).unwrap() and static mut"#;
    let plain = "multi-line strings count their \
                 continuation newlines toward line numbers";
    format!("{name}{quote}{escaped}{raw}{plain}")
}
