//! Good wire-protocol fixture: paired tags, helper indirection
//! (a tag passed as a `tag_base` argument counts as paired), error
//! propagation instead of unwraps, and a `#[cfg(test)]` module whose
//! contents must be invisible to every rule.

pub const TAG_PING: u32 = 0x0100_0000;
pub const TAG_PONG: u32 = 0x0200_0000;
pub const TAG_BULK: u32 = 0x0300_0000;
pub const CT_ALPHA: u32 = 0x1;
pub const CT_OMEGA: u32 = 0xF;

pub fn ping(comm: &mut Comm, buf: Vec<u8>) -> Result<(), CommError> {
    comm.send(1, TAG_PING, buf);
    let msgs = comm.recv_tagged(TAG_PONG, 1, TIMEOUT)?;
    comm.send(0, TAG_PONG, msgs.into_iter().next().unwrap().data);
    let _echo = comm.recv_tagged(TAG_PING, 1, TIMEOUT)?;
    bulk_exchange(comm, TAG_BULK)
}

fn bulk_exchange(comm: &mut Comm, tag_base: u32) -> Result<(), CommError> {
    comm.send(1, tag_base, Vec::new());
    let _ = comm.recv_tagged(tag_base, 1, TIMEOUT)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use std::collections::HashMap;

    #[test]
    fn invisible_to_the_linter() {
        let _m: HashMap<u32, u32> = HashMap::new();
        let _t = std::time::Instant::now();
        let _o = comm.recv_tagged(TAG_PING, 1, TIMEOUT).unwrap();
    }
}
