//! Fixture lockdown for difflb-lint: the bad corpus must produce
//! exactly the findings below (rule, file, line and message), the
//! good corpus must produce none, and the real source tree must be
//! clean. The expected strings were cross-validated against
//! `tools/lint_report.py` on the same corpora — if these tests and
//! the CI twin-diff both pass, the two implementations agree.

use std::path::{Path, PathBuf};

fn fixture_root(which: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("fixtures").join(which)
}

fn rendered(root: &Path) -> Vec<String> {
    let files = difflb_lint::load_files(root).expect("fixture tree readable");
    difflb_lint::analyze(&files).iter().map(ToString::to_string).collect()
}

#[test]
fn bad_corpus_findings_are_exact() {
    let expect = vec![
        "distributed/proto.rs:5: [hash-map] HashMap/HashSet in a decision-path module; use BTreeMap/BTreeSet or a sorted drain",
        "distributed/proto.rs:8: [tag-collision] tag TAG_B shares namespace byte 0x01 with TAG_A",
        "distributed/proto.rs:8: [tag-unpaired] tag TAG_B is sent but never received",
        "distributed/proto.rs:9: [tag-collision] tag namespace constant TAG_LOW = 0x02000001 sets low-24 bits (namespaces are the top byte)",
        "distributed/proto.rs:9: [tag-unpaired] tag TAG_LOW is never used",
        "distributed/proto.rs:10: [tag-unpaired] tag TAG_ONEWAY is sent but never received",
        "distributed/proto.rs:11: [tag-unpaired] tag TAG_ORPHAN is received but never sent",
        "distributed/proto.rs:12: [tag-unpaired] tag TAG_DEAD is never used",
        "distributed/proto.rs:13: [ctrl-ns] CTRL_NS outside the epoch layer (allowed: simnet/network.rs, distributed/epoch.rs)",
        "distributed/proto.rs:19: [comm-unwrap] Comm result unwrapped; propagate CommError so recovery stays reachable",
        "distributed/proto.rs:21: [flag-guarded-send] comm call inside a telemetry-flag conditional (wire sequence must not depend on obs flags)",
        "distributed/proto.rs:23: [hash-map] HashMap/HashSet in a decision-path module; use BTreeMap/BTreeSet or a sorted drain",
        "distributed/proto.rs:27: [ctrl-kind-budget] ctrl kind CT_WIDE = 0x10 overflows the 4-bit kind field (map tags pack the LB round from bit 4 up)",
        "distributed/proto.rs:28: [ctrl-kind-budget] ctrl kind CT_DUP reuses value 0x1 of CT_OK",
        "model/graph.rs:3: [hash-map] HashMap/HashSet in a decision-path module; use BTreeMap/BTreeSet or a sorted drain",
        "model/graph.rs:5: [hash-map] HashMap/HashSet in a decision-path module; use BTreeMap/BTreeSet or a sorted drain",
        "model/graph.rs:8: [partial-cmp] partial_cmp().unwrap() on floats; use total_cmp",
        "strategies/diffusion/object_selection.rs:4: [soa-index] seed-era by-node object index in a stage-3 hot path; walk LbScratch's sorted-by-node SoA slices",
        "strategies/diffusion/object_selection.rs:7: [soa-index] seed-era by-node object index in a stage-3 hot path; walk LbScratch's sorted-by-node SoA slices",
        "strategies/diffusion/object_selection.rs:8: [soa-index] seed-era by-node object index in a stage-3 hot path; walk LbScratch's sorted-by-node SoA slices",
        "strategies/diffusion/object_selection.rs:9: [soa-index] seed-era by-node object index in a stage-3 hot path; walk LbScratch's sorted-by-node SoA slices",
        "strategies/pick.rs:3: [hash-map] HashMap/HashSet in a decision-path module; use BTreeMap/BTreeSet or a sorted drain",
        "strategies/pick.rs:5: [static-mut] static mut is a data race waiting to happen; use atomics or OnceLock",
        "strategies/pick.rs:7: [hash-map] HashMap/HashSet in a decision-path module; use BTreeMap/BTreeSet or a sorted drain",
        "strategies/pick.rs:8: [wall-clock] wall-clock read outside obs/; annotate if this is measurement, not decision input",
        "strategies/pick.rs:9: [partial-cmp] partial_cmp().unwrap() on floats; use total_cmp",
        "strategies/pick.rs:10: [hash-map] HashMap/HashSet in a decision-path module; use BTreeMap/BTreeSet or a sorted drain",
        "util/stats.rs:8: [wall-clock] wall-clock read outside obs/; annotate if this is measurement, not decision input",
        "util/stats.rs:9: [partial-cmp] partial_cmp().unwrap() on floats; use total_cmp",
    ];
    assert_eq!(rendered(&fixture_root("bad")), expect);
}

#[test]
fn bad_corpus_tag_table_is_exact() {
    let files = difflb_lint::load_files(&fixture_root("bad")).expect("fixture tree readable");
    let expect = "\
TAG_A 0x01000000 distributed/proto.rs sends=1 recvs=1 other=0
TAG_B 0x01000000 distributed/proto.rs sends=1 recvs=0 other=0
TAG_LOW 0x02000001 distributed/proto.rs sends=0 recvs=0 other=0
TAG_ONEWAY 0x03000000 distributed/proto.rs sends=1 recvs=0 other=0
TAG_ORPHAN 0x04000000 distributed/proto.rs sends=0 recvs=1 other=0
TAG_DEAD 0x05000000 distributed/proto.rs sends=0 recvs=0 other=0
CTRL_NS 0x7f000000 distributed/proto.rs sends=0 recvs=0 other=0
";
    assert_eq!(difflb_lint::tag_table(&files), expect);
}

#[test]
fn good_corpus_is_clean() {
    let findings = rendered(&fixture_root("good"));
    assert!(findings.is_empty(), "good corpus must be clean, got:\n{}", findings.join("\n"));
}

/// The real source tree must be clean: every true finding was fixed,
/// every deliberate exception carries an inline allow annotation.
#[test]
fn rust_src_is_clean() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../src");
    let findings = rendered(&root);
    assert!(findings.is_empty(), "rust/src must lint clean, got:\n{}", findings.join("\n"));
}

/// Wire-protocol sanity on the real tree: the tag table is non-empty,
/// namespaces are unique, and the protocol tags everyone relies on
/// are present (a rename would silently drop them from the checker).
#[test]
fn rust_src_tag_table_covers_the_protocol() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../src");
    let files = difflb_lint::load_files(&root).expect("src tree readable");
    let table = difflb_lint::tag_table(&files);
    for name in ["TAG_HANDSHAKE", "TAG_STAGE2", "TAG_STAGE3", "TAG_STEP", "TAG_MIG", "TAG_FIN", "CTRL_NS"] {
        assert!(table.contains(name), "tag {name} missing from table:\n{table}");
    }
}
