#!/usr/bin/env python3
"""Toolchain-free cross-checks for the zero-allocation LB refactor.

The build container ships no Rust toolchain (see EXPERIMENTS.md §Perf),
so the refactor's bit-identity claims were validated by simulating both
the seed and the refactored algorithms here and asserting identical
decisions. `cargo test` (rust/tests/perf_refactor.rs) re-proves the
same properties natively wherever a toolchain exists; this script is
the in-container fallback and documents exactly what was checked:

1. `CommGraph::from_edges`: the seed's HashMap entry-merge vs the new
   canonicalize + stable-sort + sum-merge produce bit-identical CSR
   arrays (offsets, neighbor order, weight sums), because the stable
   sort preserves each key's input accumulation order.
2. Stage-3 `select_comm`: the seed's per-(node, neighbor) HashMap +
   fresh BinaryHeap vs the dense `bytes_to_j` + epoch-tag scratch make
   identical migration decisions, including when candidate scoring is
   chunked as the thread pool would chunk it.

Run: python3 tools/crosscheck_refactor.py
"""

import heapq
import random
import sys


# ------------------------------------------------------------ check 1

def seed_from_edges(n, edges):
    merged = {}
    for a, b, w in edges:
        if a == b:
            continue
        k = (a, b) if a < b else (b, a)
        merged[k] = merged.get(k, 0.0) + w
    deg = [0] * n
    for a, b in merged:
        deg[a] += 1
        deg[b] += 1
    off = [0] * (n + 1)
    for i in range(n):
        off[i + 1] = off[i] + deg[i]
    nbrs = [0] * off[n]
    byts = [0.0] * off[n]
    cur = off[:n]
    for (a, b) in sorted(merged):
        w = merged[(a, b)]
        nbrs[cur[a]] = b
        byts[cur[a]] = w
        cur[a] += 1
        nbrs[cur[b]] = a
        byts[cur[b]] = w
        cur[b] += 1
    return off, nbrs, byts


def new_from_edges(n, edges):
    canon = []
    for a, b, w in edges:
        if a > b:
            a, b = b, a
        if a != b:
            canon.append([a, b, w])
    canon.sort(key=lambda e: (e[0], e[1]))  # stable, like Rust sort_by_key
    merged = []
    for e in canon:
        if merged and merged[-1][0] == e[0] and merged[-1][1] == e[1]:
            merged[-1][2] += e[2]
        else:
            merged.append(e[:])
    off = [0] * (n + 1)
    for a, b, _ in merged:
        off[a + 1] += 1
        off[b + 1] += 1
    for i in range(n):
        off[i + 1] += off[i]
    nbrs = [0] * off[n]
    byts = [0.0] * off[n]
    cur = off[:n]
    for a, b, w in merged:
        nbrs[cur[a]] = b
        byts[cur[a]] = w
        cur[a] += 1
        nbrs[cur[b]] = a
        byts[cur[b]] = w
        cur[b] += 1
    return off, nbrs, byts


def check_csr(trials=200):
    rng = random.Random(1)
    for trial in range(trials):
        n = rng.randint(2, 40)
        m = rng.randint(0, 120)
        edges = [
            (rng.randrange(n), rng.randrange(n), rng.uniform(0.1, 9.9))
            for _ in range(m)
        ]
        assert seed_from_edges(n, edges) == new_from_edges(n, edges), trial
    print(f"check 1 — from_edges CSR identity: {trials}/{trials} trials bit-identical")


# ------------------------------------------------------------ check 2

def mk_adj(n, extra, rng):
    edges = [(o, (o + 1) % n, rng.uniform(1, 100)) for o in range(n)]
    for _ in range(extra):
        edges.append((rng.randrange(n), rng.randrange(n), rng.uniform(1, 100)))
    merged = {}
    for a, b, w in edges:
        if a == b:
            continue
        k = (min(a, b), max(a, b))
        merged[k] = merged.get(k, 0.0) + w
    adj = [[] for _ in range(n)]
    for (a, b), w in sorted(merged.items()):
        adj[a].append((b, w))
        adj[b].append((a, w))
    for r in adj:
        r.sort()
    return adj


def fits(load, remaining, overfill):
    return remaining > 0.0 and load * (1.0 - overfill) <= remaining


def sorted_targets(quotas_row, floor):
    return sorted(
        [(j, a) for j, a in quotas_row.items() if a >= floor],
        key=lambda t: (-t[1], t[0]),
    )


def seed_select(n_nodes, node_map, loads, adj, quotas, overfill, floor):
    """The seed: per-(i, j) HashMap + fresh heap."""
    moved = [False] * len(node_map)
    migr = 0
    by_node = [[] for _ in range(n_nodes)]
    for o, nm in enumerate(node_map):
        by_node[nm].append(o)
    for i in range(n_nodes):
        targets = sorted_targets(quotas[i], floor)
        if not targets:
            continue
        pool = [o for o in by_node[i] if node_map[o] == i and not moved[o]]
        for j, quota in targets:
            remaining = quota
            b2j = {}
            heap = []
            for o in pool:
                if moved[o] or node_map[o] != i:
                    continue
                bj = 0.0
                local = 0.0
                for p, w in adj[o]:
                    pn = node_map[p]
                    if pn == j:
                        bj += w
                    elif pn == i:
                        local += w
                b2j[o] = bj
                heapq.heappush(heap, (-bj, local, o))
            while remaining > 1e-12 and heap:
                nk, tie, o = heapq.heappop(heap)
                k = -nk
                if moved[o] or node_map[o] != i:
                    continue
                cur = b2j[o]
                if abs(cur - k) > 1e-9:
                    heapq.heappush(heap, (-cur, tie, o))
                    continue
                load = loads[o]
                if not fits(load, remaining, overfill):
                    continue
                node_map[o] = j
                moved[o] = True
                migr += 1
                remaining -= load
                for p, w in adj[o]:
                    if node_map[p] == i and not moved[p] and p in b2j:
                        b2j[p] += w
                        heapq.heappush(heap, (-b2j[p], 0.0, p))
    return migr


def new_select(n_nodes, node_map, loads, adj, quotas, overfill, floor, chunks):
    """The refactor: dense bytes_to_j + epoch tags, chunked scoring."""
    nobj = len(node_map)
    moved = [False] * nobj
    migr = 0
    by_node = [[] for _ in range(n_nodes)]
    for o, nm in enumerate(node_map):
        by_node[nm].append(o)
    b2j = [0.0] * nobj
    epoch = [0] * nobj
    cur_ep = 0
    for i in range(n_nodes):
        targets = sorted_targets(quotas[i], floor)
        if not targets:
            continue
        pool = [o for o in by_node[i] if node_map[o] == i and not moved[o]]
        for j, quota in targets:
            remaining = quota
            cur_ep += 1
            scores = [None] * len(pool)
            chunk = max(1, (len(pool) + chunks - 1) // chunks)
            for c in range(chunks):
                for p in range(c * chunk, min(len(pool), (c + 1) * chunk)):
                    o = pool[p]
                    if moved[o] or node_map[o] != i:
                        continue
                    bj = 0.0
                    local = 0.0
                    for q, w in adj[o]:
                        pn = node_map[q]
                        if pn == j:
                            bj += w
                        elif pn == i:
                            local += w
                    scores[p] = (bj, local)
            heap = []
            for p, o in enumerate(pool):
                if scores[p] is None:
                    continue
                bj, local = scores[p]
                b2j[o] = bj
                epoch[o] = cur_ep
                heapq.heappush(heap, (-bj, local, o))
            while remaining > 1e-12 and heap:
                nk, tie, o = heapq.heappop(heap)
                k = -nk
                if moved[o] or node_map[o] != i:
                    continue
                cur = b2j[o]
                if abs(cur - k) > 1e-9:
                    heapq.heappush(heap, (-cur, tie, o))
                    continue
                load = loads[o]
                if not fits(load, remaining, overfill):
                    continue
                node_map[o] = j
                moved[o] = True
                migr += 1
                remaining -= load
                for p, w in adj[o]:
                    if node_map[p] == i and not moved[p] and epoch[p] == cur_ep:
                        b2j[p] += w
                        heapq.heappush(heap, (-b2j[p], 0.0, p))
    return migr


def check_select(trials=60):
    rng = random.Random(3)
    for trial in range(trials):
        n = rng.randint(20, 300)
        n_nodes = rng.randint(2, 6)
        adj = mk_adj(n, n, rng)
        loads = [rng.uniform(0.5, 2.0) for _ in range(n)]
        node_map = [rng.randrange(n_nodes) for _ in range(n)]
        quotas = [{} for _ in range(n_nodes)]
        for i in range(n_nodes):
            for j in range(n_nodes):
                if i != j and rng.random() < 0.5:
                    quotas[i][j] = rng.uniform(0, 20)
        floor = 0.01 * sum(loads) / n_nodes
        m1, m2, m3 = list(node_map), list(node_map), list(node_map)
        r1 = seed_select(n_nodes, m1, loads, adj, quotas, 0.5, floor)
        r2 = new_select(n_nodes, m2, loads, adj, quotas, 0.5, floor, chunks=1)
        r3 = new_select(n_nodes, m3, loads, adj, quotas, 0.5, floor, chunks=7)
        assert (r1, m1) == (r2, m2) == (r3, m3), trial
    print(
        f"check 2 — seed vs refactored select_comm (chunks 1 and 7): "
        f"{trials}/{trials} trials identical"
    )


if __name__ == "__main__":
    check_csr()
    check_select()
    print("all cross-checks passed")
    sys.exit(0)
