#!/usr/bin/env python3
"""Regex/lexer twin of difflb-lint (rust/lint) for in-container use.

Implements the same rule set over the same file scoping so the two can
cross-validate each other: CI diffs `difflb-lint --tags` against
`lint_report.py --tags` (the wire-protocol tag table must be
byte-identical), and both must report zero findings on rust/src.

Rules (ids shared with the Rust implementation):
  tag-collision      TAG_*/CTRL_NS namespace constants must keep the low
                     24 bits clear and own a unique top byte
  tag-unpaired       every tag must be both sent and received (helper
                     indirection — tag passed as a tag_base — counts)
  ctrl-ns            CTRL_NS is confined to simnet/network.rs and
                     distributed/epoch.rs
  ctrl-kind-budget   CT_* control-message kinds must fit the 4-bit kind
                     field (< 0x10) and be unique — map tags pack the
                     LB round from bit 4 up
  flag-guarded-send  no send/recv_tagged/barrier inside a conditional on
                     tracing_enabled()/metrics_enabled()
  hash-map           no HashMap/HashSet in strategies/, model/,
                     distributed/
  partial-cmp        no .partial_cmp(..).unwrap()/unwrap_or()/expect()
  wall-clock         no Instant::now/SystemTime::now outside obs/,
                     util/bench.rs, util/logging.rs
  static-mut         no `static mut` anywhere
  comm-unwrap        no .unwrap()/.expect() chained on
                     recv_tagged()/barrier() in distributed/
  soa-index          no seed-era by_node/node_objects per-node object
                     indexes in the SoA stage-3 hot paths
                     (strategies/diffusion/object_selection.rs,
                     strategies/diffusion/hierarchical.rs,
                     distributed/stage3.rs)

Inline suppression: `// difflb-lint: allow(<rule>): <reason>` on the
finding's line or the line directly above it.

Usage:
  python3 tools/lint_report.py [--tags] [root]      (default root: rust/src)
"""

import sys
from pathlib import Path

WORD = set("abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789_")
ALLOW_MARK = "difflb-lint: allow("


def clean_source(src):
    """Blank comments, strings and char literals (newlines preserved),
    collecting allow-annotations from line comments. Returns
    (cleaned:str, allows:dict line->set(rule))."""
    n = len(src)
    out = list(src)
    allows = {}
    line = 1
    i = 0

    def blank(j):
        if out[j] != "\n":
            out[j] = " "

    def note_allow(text, at_line):
        k = text.find(ALLOW_MARK)
        while k != -1:
            start = k + len(ALLOW_MARK)
            end = text.find(")", start)
            if end == -1:
                break
            rule = text[start:end].strip()
            for ln in (at_line, at_line + 1):
                allows.setdefault(ln, set()).add(rule)
            k = text.find(ALLOW_MARK, end)

    while i < n:
        c = src[i]
        if c == "\n":
            line += 1
            i += 1
            continue
        if c == "/" and i + 1 < n and src[i + 1] == "/":
            j = i
            while j < n and src[j] != "\n":
                j += 1
            note_allow(src[i:j], line)
            for k in range(i, j):
                blank(k)
            i = j
            continue
        if c == "/" and i + 1 < n and src[i + 1] == "*":
            depth = 1
            j = i + 2
            while j < n and depth > 0:
                if src[j] == "\n":
                    line += 1
                if src[j : j + 2] == "/*":
                    depth += 1
                    j += 2
                elif src[j : j + 2] == "*/":
                    depth -= 1
                    j += 2
                else:
                    j += 1
            for k in range(i, j):
                blank(k)
            i = j
            continue
        # raw strings: r"..." / r#"..."# (optional b prefix)
        if c in "rb":
            j = i
            if src[j] == "b":
                j += 1
            if j < n and src[j] == "r":
                j += 1
                hashes = 0
                while j < n and src[j] == "#":
                    hashes += 1
                    j += 1
                if j < n and src[j] == '"':
                    closer = '"' + "#" * hashes
                    end = src.find(closer, j + 1)
                    end = n if end == -1 else end + len(closer)
                    line += src.count("\n", i, end)
                    for k in range(i, end):
                        blank(k)
                    i = end
                    continue
        if c == '"' or (c == "b" and i + 1 < n and src[i + 1] == '"'):
            j = i + (2 if c == "b" else 1)
            while j < n:
                if src[j] == "\\":
                    # escape: count a line-continuation's newline too
                    if j + 1 < n and src[j + 1] == "\n":
                        line += 1
                    j += 2
                    continue
                if src[j] == "\n":
                    line += 1
                if src[j] == '"':
                    j += 1
                    break
                j += 1
            for k in range(i, j):
                blank(k)
            i = j
            continue
        if c == "'":
            # char literal vs lifetime: 'x' or '\x' is a literal
            if i + 1 < n and src[i + 1] == "\\":
                j = i + 2
                while j < n and src[j] != "'":
                    j += 1
                j += 1
                for k in range(i, j):
                    blank(k)
                i = j
                continue
            if i + 2 < n and src[i + 2] == "'":
                for k in range(i, i + 3):
                    blank(k)
                i += 3
                continue
            i += 1
            continue
        i += 1
    return "".join(out), allows


def blank_cfg_test(cleaned):
    """Blank `#[cfg(test)]` items (the following brace-matched block)."""
    out = list(cleaned)
    pos = 0
    attr = "#[cfg(test)]"
    while True:
        start = cleaned.find(attr, pos)
        if start == -1:
            break
        brace = cleaned.find("{", start)
        if brace == -1:
            break
        depth = 0
        end = brace
        while end < len(cleaned):
            if cleaned[end] == "{":
                depth += 1
            elif cleaned[end] == "}":
                depth -= 1
                if depth == 0:
                    end += 1
                    break
            end += 1
        for k in range(start, end):
            if out[k] != "\n":
                out[k] = " "
        pos = end
    return "".join(out)


def line_starts_of(text):
    starts = [0]
    for i, c in enumerate(text):
        if c == "\n":
            starts.append(i + 1)
    return starts


def line_of(pos, starts):
    lo, hi = 0, len(starts) - 1
    while lo < hi:
        mid = (lo + hi + 1) // 2
        if starts[mid] <= pos:
            lo = mid
        else:
            hi = mid - 1
    return lo + 1


def word_occurrences(text, word):
    out = []
    i = text.find(word)
    while i != -1:
        before_ok = i == 0 or text[i - 1] not in WORD
        after = i + len(word)
        after_ok = after >= len(text) or text[after] not in WORD
        if before_ok and after_ok:
            out.append(i)
        i = text.find(word, i + 1)
    return out


def enclosing_call(text, pos):
    """Identifier of the innermost call whose argument list contains
    `pos`, or '' if the occurrence is not inside a call."""
    depth = 0
    i = pos - 1
    steps = 0
    while i >= 0 and steps < 600:
        c = text[i]
        if c == ")":
            depth += 1
        elif c == "(":
            if depth == 0:
                j = i - 1
                k = j
                while k >= 0 and text[k] in WORD:
                    k -= 1
                return text[k + 1 : j + 1]
            depth -= 1
        elif c in ";{}" and depth == 0:
            return ""
        i -= 1
        steps += 1
    return ""


def match_paren(text, open_pos):
    depth = 0
    i = open_pos
    while i < len(text):
        if text[i] == "(":
            depth += 1
        elif text[i] == ")":
            depth -= 1
            if depth == 0:
                return i
        i += 1
    return -1


def chained_method(text, after):
    """Skip whitespace after position `after`; if the next token is a
    `.method`, return the method name, else ''."""
    i = after
    while i < len(text) and text[i] in " \t\n":
        i += 1
    if i >= len(text) or text[i] != ".":
        return ""
    i += 1
    j = i
    while j < len(text) and text[j] in WORD:
        j += 1
    return text[i:j]


class File:
    def __init__(self, root, rel):
        self.rel = rel
        src = (root / rel).read_text()
        cleaned, self.allows = clean_source(src)
        self.text = blank_cfg_test(cleaned)
        self.starts = line_starts_of(self.text)

    def line(self, pos):
        return line_of(pos, self.starts)


def is_wire_file(rel):
    return rel.startswith("distributed/") or rel.startswith("simnet/")


def hash_map_scoped(rel):
    return (
        rel.startswith("strategies/")
        or rel.startswith("model/")
        or rel.startswith("distributed/")
    )


def wall_clock_allowed(rel):
    return rel.startswith("obs/") or rel in ("util/bench.rs", "util/logging.rs")


def soa_scoped(rel):
    return rel in (
        "strategies/diffusion/object_selection.rs",
        "strategies/diffusion/hierarchical.rs",
        "distributed/stage3.rs",
    )


CTRL_NS_ALLOWED = ("simnet/network.rs", "distributed/epoch.rs")


def extract_tags(files):
    """-> list of (name, value, rel, line), in (rel, line) order."""
    return extract_consts(
        files, lambda name: name.startswith("TAG_") or name == "CTRL_NS"
    )


def extract_ctrl_kinds(files):
    """CT_* control-message kinds, in (rel, line) order."""
    return extract_consts(files, lambda name: name.startswith("CT_"))


def extract_consts(files, want):
    tags = []
    for f in files:
        if not is_wire_file(f.rel):
            continue
        for pos in word_occurrences(f.text, "const"):
            i = pos + len("const")
            while i < len(f.text) and f.text[i] in " \t":
                i += 1
            j = i
            while j < len(f.text) and f.text[j] in WORD:
                j += 1
            name = f.text[i:j]
            if not want(name):
                continue
            rest = f.text[j : j + 80]
            k = 0
            while k < len(rest) and rest[k] in " \t":
                k += 1
            if not rest[k:].startswith(":"):
                continue
            eq = rest.find("=", k)
            semi = rest.find(";", k)
            if eq == -1 or semi == -1 or eq > semi:
                continue
            lit = rest[eq + 1 : semi].strip().replace("_", "")
            try:
                value = int(lit, 0)
            except ValueError:
                continue
            tags.append((name, value, f.rel, f.line(pos)))
    return tags


def classify_uses(files, tags):
    """-> dict name -> dict(send=, recv=, other=)."""
    defs = {(rel, line) for (_, _, rel, line) in tags}
    counts = {name: {"send": 0, "recv": 0, "other": 0} for (name, _, _, _) in tags}
    for f in files:
        if not is_wire_file(f.rel):
            continue
        for name, _, _, _ in tags:
            for pos in word_occurrences(f.text, name):
                if (f.rel, f.line(pos)) in defs:
                    continue
                ident = enclosing_call(f.text, pos)
                if ident == "send":
                    counts[name]["send"] += 1
                elif ident in ("recv_tagged", "barrier"):
                    counts[name]["recv"] += 1
                else:
                    counts[name]["other"] += 1
    return counts


def wire_findings(files, tags, counts, emit):
    seen_ns = {}
    for name, value, rel, line in tags:
        if value & 0x00FF_FFFF:
            emit(
                rel,
                line,
                "tag-collision",
                f"tag namespace constant {name} = 0x{value:08x} sets low-24 bits "
                "(namespaces are the top byte)",
            )
        ns = value >> 24
        if ns in seen_ns:
            emit(
                rel,
                line,
                "tag-collision",
                f"tag {name} shares namespace byte 0x{ns:02x} with {seen_ns[ns]}",
            )
        else:
            seen_ns[ns] = name
    seen_kind = {}
    for name, value, rel, line in extract_ctrl_kinds(files):
        if value >= 0x10:
            emit(
                rel,
                line,
                "ctrl-kind-budget",
                f"ctrl kind {name} = 0x{value:x} overflows the 4-bit kind field "
                "(map tags pack the LB round from bit 4 up)",
            )
        if value in seen_kind:
            emit(
                rel,
                line,
                "ctrl-kind-budget",
                f"ctrl kind {name} reuses value 0x{value:x} of {seen_kind[value]}",
            )
        else:
            seen_kind[value] = name
    for name, value, rel, line in tags:
        if name == "CTRL_NS":
            continue
        c = counts[name]
        total = c["send"] + c["recv"] + c["other"]
        if total == 0:
            emit(rel, line, "tag-unpaired", f"tag {name} is never used")
        elif c["send"] > 0 and c["recv"] == 0 and c["other"] == 0:
            emit(rel, line, "tag-unpaired", f"tag {name} is sent but never received")
        elif c["recv"] > 0 and c["send"] == 0 and c["other"] == 0:
            emit(rel, line, "tag-unpaired", f"tag {name} is received but never sent")

    for f in files:
        if not is_wire_file(f.rel):
            continue
        if f.rel not in CTRL_NS_ALLOWED:
            for pos in word_occurrences(f.text, "CTRL_NS"):
                emit(
                    f.rel,
                    f.line(pos),
                    "ctrl-ns",
                    "CTRL_NS outside the epoch layer "
                    "(allowed: simnet/network.rs, distributed/epoch.rs)",
                )
        # flag-guarded comm calls
        for pos in word_occurrences(f.text, "if"):
            brace = -1
            depth = 0
            i = pos + 2
            while i < len(f.text) and i < pos + 300:
                c = f.text[i]
                if c == "(":
                    depth += 1
                elif c == ")":
                    depth -= 1
                elif c == "{" and depth == 0:
                    brace = i
                    break
                elif c == ";":
                    break
                i += 1
            if brace == -1:
                continue
            cond = f.text[pos:brace]
            if "tracing_enabled" not in cond and "metrics_enabled" not in cond:
                continue
            depth = 0
            end = brace
            while end < len(f.text):
                if f.text[end] == "{":
                    depth += 1
                elif f.text[end] == "}":
                    depth -= 1
                    if depth == 0:
                        break
                end += 1
            block = f.text[brace:end]
            for call in (".send(", ".recv_tagged(", ".barrier("):
                k = block.find(call)
                while k != -1:
                    emit(
                        f.rel,
                        f.line(brace + k),
                        "flag-guarded-send",
                        "comm call inside a telemetry-flag conditional "
                        "(wire sequence must not depend on obs flags)",
                    )
                    k = block.find(call, k + 1)


def determinism_findings(f, emit):
    text = f.text
    if hash_map_scoped(f.rel):
        lines_hit = set()
        for word in ("HashMap", "HashSet"):
            for pos in word_occurrences(text, word):
                lines_hit.add(f.line(pos))
        for ln in sorted(lines_hit):
            emit(
                f.rel,
                ln,
                "hash-map",
                "HashMap/HashSet in a decision-path module; "
                "use BTreeMap/BTreeSet or a sorted drain",
            )
    for pos in word_occurrences(text, "partial_cmp"):
        if pos == 0 or text[pos - 1] != ".":
            continue
        open_pos = pos + len("partial_cmp")
        if open_pos >= len(text) or text[open_pos] != "(":
            continue
        close = match_paren(text, open_pos)
        if close == -1:
            continue
        nxt = chained_method(text, close + 1)
        if nxt in ("unwrap", "unwrap_or", "unwrap_or_else", "expect"):
            emit(
                f.rel,
                f.line(pos),
                "partial-cmp",
                "partial_cmp().unwrap() on floats; use total_cmp",
            )
    if not wall_clock_allowed(f.rel):
        for pat in ("Instant::now", "SystemTime::now"):
            for pos in word_occurrences(text, pat.split("::")[0]):
                if text[pos:].startswith(pat):
                    emit(
                        f.rel,
                        f.line(pos),
                        "wall-clock",
                        "wall-clock read outside obs/; "
                        "annotate if this is measurement, not decision input",
                    )
    for pos in word_occurrences(text, "static"):
        rest = text[pos + len("static") :]
        k = 0
        while k < len(rest) and rest[k] in " \t":
            k += 1
        if rest[k:].startswith("mut") and (
            k + 3 >= len(rest) or rest[k + 3] not in WORD
        ):
            emit(
                f.rel,
                f.line(pos),
                "static-mut",
                "static mut is a data race waiting to happen; "
                "use atomics or OnceLock",
            )
    if soa_scoped(f.rel):
        lines_hit = set()
        for word in ("by_node", "node_objects"):
            for pos in word_occurrences(text, word):
                lines_hit.add(f.line(pos))
        for ln in sorted(lines_hit):
            emit(
                f.rel,
                ln,
                "soa-index",
                "seed-era by-node object index in a stage-3 hot path; "
                "walk LbScratch's sorted-by-node SoA slices",
            )
    if f.rel.startswith("distributed/"):
        for word in ("recv_tagged", "barrier"):
            for pos in word_occurrences(text, word):
                if pos == 0 or text[pos - 1] != ".":
                    continue
                open_pos = pos + len(word)
                if open_pos >= len(text) or text[open_pos] != "(":
                    continue
                close = match_paren(text, open_pos)
                if close == -1:
                    continue
                nxt = chained_method(text, close + 1)
                if nxt in ("unwrap", "unwrap_or", "unwrap_or_else", "expect"):
                    emit(
                        f.rel,
                        f.line(pos),
                        "comm-unwrap",
                        "Comm result unwrapped; propagate CommError "
                        "so recovery stays reachable",
                    )


def main():
    args = [a for a in sys.argv[1:]]
    tags_mode = "--tags" in args
    args = [a for a in args if a != "--tags"]
    root = Path(args[0] if args else "rust/src")
    rels = sorted(
        str(p.relative_to(root)).replace("\\", "/")
        for p in root.rglob("*.rs")
    )
    files = [File(root, rel) for rel in rels]

    tags = extract_tags(files)
    counts = classify_uses(files, tags)

    if tags_mode:
        for name, value, rel, _line in sorted(tags, key=lambda t: (t[1], t[0])):
            c = counts[name]
            print(
                f"{name} 0x{value:08x} {rel} "
                f"sends={c['send']} recvs={c['recv']} other={c['other']}"
            )
        return 0

    findings = []

    def emit(rel, line, rule, msg):
        f = next(f for f in files if f.rel == rel)
        if rule in f.allows.get(line, set()):
            return
        findings.append((rel, line, rule, msg))

    wire_findings(files, tags, counts, emit)
    for f in files:
        determinism_findings(f, emit)

    findings.sort()
    for rel, line, rule, msg in findings:
        print(f"{rel}:{line}: [{rule}] {msg}")
    print(
        f"{len(findings)} finding(s) across {len(files)} file(s)",
        file=sys.stderr,
    )
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
