#!/usr/bin/env python3
"""Inspect and validate difflb telemetry exports (ISSUE 7).

Two artifacts come out of a run with telemetry enabled:

  * ``--trace out.json``    — Chrome trace-event JSON of the run's
    spans (``rust/src/obs/trace.rs::write_chrome_trace``): complete
    ``X`` events plus thread-scoped ``i`` instants, timestamps in
    microseconds of cluster-coherent virtual time, ``tid`` = simnet
    rank. Loadable in chrome://tracing or Perfetto as-is.
  * ``--metrics out.jsonl`` — one JSON object per LB round
    (``rust/src/obs/metrics.rs``) with the fixed key set below.

Default mode prints a human summary: per-(cat, name) span aggregates,
per-rank event counts, instant markers, and the per-round metrics
table. ``--check`` validates the schemas instead and exits non-zero on
the first violation — the CI trace-smoke job runs it against short
sequential and distributed runs.

Usage:
  python3 tools/trace_report.py trace.json [metrics.jsonl]
  python3 tools/trace_report.py --check trace.json [metrics.jsonl]
  python3 tools/trace_report.py --check --require stage2.virtual,migrate trace.json
"""

import argparse
import json
import sys

# The exact key set of one metrics JSONL record (obs/metrics.rs
# to_json_line). `imbalance`/`time_max_avg` may be null (non-finite
# values have no JSON representation).
METRIC_KEYS = {
    "round": int,
    "iter": int,
    "imbalance": (int, float, type(None)),
    "time_max_avg": (int, float, type(None)),
    "migrations": int,
    "comm_s": (int, float, type(None)),
    "lb_s": (int, float, type(None)),
    "stage2_iters": int,
    "stale_drops": int,
    "epochs": int,
}

TRACE_PHASES = {"X", "i"}


def fail(msg):
    print(f"trace_report: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def load_trace(path):
    with open(path) as f:
        try:
            doc = json.load(f)
        except json.JSONDecodeError as e:
            fail(f"{path}: not valid JSON: {e}")
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        fail(f"{path}: missing traceEvents array")
    events = doc["traceEvents"]
    if not isinstance(events, list):
        fail(f"{path}: traceEvents is not a list")
    return events


def check_trace(events, path, require):
    last_ts = -1
    names = set()
    for i, e in enumerate(events):
        ctx = f"{path}: event {i}"
        if not isinstance(e, dict):
            fail(f"{ctx}: not an object")
        for key in ("name", "cat", "ph", "ts", "pid", "tid"):
            if key not in e:
                fail(f"{ctx}: missing '{key}'")
        if not isinstance(e["name"], str) or not e["name"]:
            fail(f"{ctx}: bad name {e['name']!r}")
        if e["ph"] not in TRACE_PHASES:
            fail(f"{ctx}: unknown phase {e['ph']!r}")
        if not isinstance(e["ts"], int) or e["ts"] < 0:
            fail(f"{ctx}: bad ts {e['ts']!r}")
        if not isinstance(e["tid"], int) or e["tid"] < 0:
            fail(f"{ctx}: bad tid {e['tid']!r}")
        if e["ph"] == "X":
            if not isinstance(e.get("dur"), int) or e["dur"] < 0:
                fail(f"{ctx}: X event needs an integer dur >= 0")
        else:
            if e.get("s") != "t":
                fail(f"{ctx}: instant events must be thread-scoped")
        # the rank-merged export is ordered on virtual time — the
        # acceptance property of the cross-rank gather
        if e["ts"] < last_ts:
            fail(f"{ctx}: ts {e['ts']} < previous {last_ts} (merge not monotone)")
        last_ts = e["ts"]
        names.add(e["name"])
    for want in require:
        if want not in names:
            fail(f"{path}: required span '{want}' absent (have: {sorted(names)})")
    print(f"trace OK: {path}: {len(events)} events, {len(names)} distinct names")


def load_metrics(path):
    rounds = []
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rounds.append((lineno, json.loads(line)))
            except json.JSONDecodeError as e:
                fail(f"{path}:{lineno}: not valid JSON: {e}")
    return rounds


def check_metrics(rounds, path):
    prev_round = -1
    for lineno, rec in rounds:
        ctx = f"{path}:{lineno}"
        if not isinstance(rec, dict):
            fail(f"{ctx}: not an object")
        if set(rec) != set(METRIC_KEYS):
            fail(
                f"{ctx}: key set {sorted(rec)} != expected {sorted(METRIC_KEYS)}"
            )
        for key, ty in METRIC_KEYS.items():
            if not isinstance(rec[key], ty) or isinstance(rec[key], bool):
                fail(f"{ctx}: {key} has type {type(rec[key]).__name__}")
        if rec["round"] < prev_round:
            fail(f"{ctx}: round {rec['round']} < previous {prev_round}")
        prev_round = rec["round"]
    print(f"metrics OK: {path}: {len(rounds)} LB rounds")


def summarize_trace(events):
    spans = {}
    instants = {}
    per_tid = {}
    for e in events:
        per_tid[e.get("tid", 0)] = per_tid.get(e.get("tid", 0), 0) + 1
        key = (e.get("cat", ""), e.get("name", ""))
        if e.get("ph") == "X":
            agg = spans.setdefault(key, [0, 0, 0])
            agg[0] += 1
            agg[1] += e.get("dur", 0)
            agg[2] = max(agg[2], e.get("dur", 0))
        else:
            instants[key] = instants.get(key, 0) + 1
    print(f"{len(events)} events across {len(per_tid)} ranks "
          f"({', '.join(f'r{t}:{n}' for t, n in sorted(per_tid.items()))})")
    if spans:
        print(f"{'cat':<12} {'span':<20} {'count':>6} {'total ms':>10} "
              f"{'mean us':>9} {'max us':>8}")
        for (cat, name), (count, total, mx) in sorted(spans.items()):
            print(f"{cat:<12} {name:<20} {count:>6} {total / 1000:>10.3f} "
                  f"{total / count:>9.1f} {mx:>8}")
    for (cat, name), count in sorted(instants.items()):
        print(f"{cat:<12} {name:<20} {count:>6} marks")


def summarize_metrics(rounds):
    print(f"{'round':>5} {'iter':>5} {'imbal':>8} {'t_imbal':>8} {'migr':>5} "
          f"{'comm_s':>10} {'lb_s':>10} {'s2_it':>5} {'stale':>6} {'epoch':>5}")
    for _, r in rounds:
        fmt = lambda v, w: f"{'null':>{w}}" if v is None else f"{v:>{w}.4f}"
        print(f"{r['round']:>5} {r['iter']:>5} {fmt(r['imbalance'], 8)} "
              f"{fmt(r['time_max_avg'], 8)} {r['migrations']:>5} "
              f"{fmt(r['comm_s'], 10)} {fmt(r['lb_s'], 10)} "
              f"{r['stage2_iters']:>5} {r['stale_drops']:>6} {r['epochs']:>5}")


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="Chrome trace-event JSON (--trace output)")
    ap.add_argument("metrics", nargs="?", help="metrics JSONL (--metrics output)")
    ap.add_argument("--check", action="store_true",
                    help="validate schemas and exit non-zero on violation")
    ap.add_argument("--require", default="",
                    help="comma-separated span names that must appear (with --check)")
    args = ap.parse_args()

    events = load_trace(args.trace)
    require = [n for n in args.require.split(",") if n]
    if args.check:
        check_trace(events, args.trace, require)
    else:
        summarize_trace(events)

    if args.metrics:
        rounds = load_metrics(args.metrics)
        if args.check:
            check_metrics(rounds, args.metrics)
        else:
            summarize_metrics(rounds)


if __name__ == "__main__":
    main()
