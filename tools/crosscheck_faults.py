#!/usr/bin/env python3
"""Cross-simulation of quorum-restart recovery vs the sequential model.

The build container ships no Rust toolchain (EXPERIMENTS.md §Perf
provenance), so — like tools/crosscheck_distributed.py for the
fault-free protocols — this script mirrors the decision logic of the
recovery path in Python and asserts its outcome is bit-equal to a
from-scratch sequential run on the survivor topology:

  1. re-homing (rust/src/model/instance.rs rehome_mapping): a dead
     node's objects adopt the next alive node cyclically; objects on
     survivors never move, so restriction relabels work but never
     creates or destroys it.
  2. quorum restart: after restricting to the dense survivor set, the
     *distributed* stage-2/stage-3 protocols (the exact mirrors from
     crosscheck_distributed.py) must produce the same flows, final
     object->node map and manifests as the *sequential* model over the
     same restricted instance — i.e. a pipeline restarted on the
     surviving quorum lands on the assignment a sequential run on the
     survivor topology would have computed, and the expansion back to
     world ranks can never resurrect a dead node.
  3. partition semantics (rust/src/simnet/fault.rs cut): cuts are
     symmetric, never sever two majority-side ranks — the property
     recovery liveness rests on (the surviving quorum stays fully
     connected) — and a healed cut is gone at every later clock.
  4. leader election (rust/src/distributed/epoch.rs elect/successor):
     the coordinator is the lowest alive non-barred rank (rank 0 holds
     no privilege), the successor is the next in line, re-election
     after coordinator deaths converges in <= n steps, and barring a
     healed minority can never hand it the root back.

Run: python3 tools/crosscheck_faults.py
"""
import os
import random
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import crosscheck_distributed as xd


# ------------------------------------------------------------- rehome
# Mirrors rehome_mapping (node-level view, pes_per_node = 1).
def rehome(node_map, n_nodes, alive):
    out = []
    for node in node_map:
        if alive[node]:
            out.append(node)
            continue
        adopter = node
        for d in range(1, n_nodes + 1):
            c = (node + d) % n_nodes
            if alive[c]:
                adopter = c
                break
        out.append(adopter)
    return out


# Mirrors restrict_instance's dense renumbering: survivor world ids
# ascending, dense node i = survivors[i].
def densify(node_map, alive):
    survivors = [n for n in range(len(alive)) if alive[n]]
    dense = {w: i for i, w in enumerate(survivors)}
    return [dense[n] for n in node_map], survivors


# Mirrors FaultPlan::cut: a message a->b is dropped iff some active
# partition separates them. A partition is active from its cut round
# until its heal round (None = permanent).
def cut(partitions, a, b, clock):
    return any(
        p_round <= clock and (heal is None or clock < heal)
        and ((a in minority) != (b in minority))
        for (p_round, heal, minority) in partitions
    )


# Mirrors epoch::elect: the lowest alive non-barred rank, falling back
# to the lowest alive rank when every survivor is barred.
def elect(failed, barred):
    for r in range(len(failed)):
        if not failed[r] and not barred[r]:
            return r
    for r in range(len(failed)):
        if not failed[r]:
            return r
    return 0


# Mirrors epoch::successor: next in line after `root` under the same
# rule, or None.
def successor(failed, barred, root):
    for r in range(len(failed)):
        if r != root and not failed[r] and not barred[r]:
            return r
    return None


def quorum_restart_trials(rng, trials):
    for t in range(trials):
        n_nodes = rng.choice([4, 6, 8, 12])
        loads, graph, node_map = xd.random_instance(rng, n_nodes, rng.randint(3, 8))
        # victim set: ANY rank — including 0, the default root — as
        # long as the survivors keep quorum (2*(n-d) > n).
        max_dead = (n_nodes - 1) // 2
        dead = set(rng.sample(range(n_nodes), rng.randint(1, max(1, max_dead))))
        alive = [n not in dead for n in range(n_nodes)]

        # the elected coordinator is alive, deterministic, and agreed
        # on by every survivor (it is a pure function of shared state).
        failed = [not a for a in alive]
        coord = elect(failed, [False] * n_nodes)
        assert alive[coord], f"trial {t}: elected a dead coordinator"
        assert coord == min(n for n in range(n_nodes) if alive[n]), \
            f"trial {t}: coordinator is not the lowest survivor"

        rehomed = rehome(node_map, n_nodes, alive)
        assert all(alive[n] for n in rehomed), \
            f"trial {t}: rehome left an object on a dead node"
        for o, home in enumerate(node_map):
            if alive[home]:
                assert rehomed[o] == home, f"trial {t}: survivor object {o} moved"

        sub_map, survivors = densify(rehomed, alive)
        k = len(survivors)
        node_loads = [
            xd.sum_ltr([loads[o] for o in range(len(loads)) if sub_map[o] == i])
            for i in range(k)
        ]
        total = xd.sum_ltr(loads)
        assert abs(xd.sum_ltr(node_loads) - total) <= 1e-12 * total, \
            f"trial {t}: restriction changed total work"

        adj = xd.ring_graph(k, 1 if k <= 4 else 2)
        sflows, si = xd.seq_virtual_balance(adj, node_loads, 0.05, 200)
        dflows, di = xd.dist_virtual_balance(adj, node_loads, 0.05, 200)
        assert si == di, f"trial {t}: restart stage2 iterations {si} != {di}"
        assert sflows == dflows, f"trial {t}: restart stage2 flows diverged"

        floor = xd.quota_floor(loads, k)
        overfill = rng.choice([0.0, 0.5])
        smap, sman = xd.seq_select(graph, loads, list(sub_map), sflows, floor,
                                   overfill, k)
        dmap, dman = xd.dist_select(graph, loads, list(sub_map), sflows, floor,
                                    overfill, k)
        assert smap == dmap, f"trial {t}: restart stage3 maps diverged"
        assert sman == dman, f"trial {t}: restart stage3 manifests diverged"

        # expand back to world ranks — a dead node can never reappear
        world = [survivors[n] for n in smap]
        assert all(alive[n] for n in world), \
            f"trial {t}: expanded assignment resurrected a dead node"
    print(f"quorum restart: {trials}/{trials} trials — restarted distributed "
          "pipeline bit-equal to the sequential survivor-topology model")


def partition_property_trials(rng, trials):
    for t in range(trials):
        n = rng.randint(3, 16)
        parts = []
        for _ in range(rng.randint(1, 3)):
            # minorities may include rank 0; about half the cuts heal
            minority = set(rng.sample(range(n), rng.randint(1, (n - 1) // 2)))
            p_round = rng.randint(1, 5)
            heal = rng.randint(p_round + 1, 7) if rng.random() < 0.5 else None
            parts.append((p_round, heal, minority))
        for clock in range(9):
            majority = [r for r in range(n)
                        if all(r not in m for (p, h, m) in parts
                               if p <= clock and (h is None or clock < h))]
            for a in range(n):
                for b in range(n):
                    assert cut(parts, a, b, clock) == cut(parts, b, a, clock), \
                        f"trial {t}: cut not symmetric"
            for a in majority:
                for b in majority:
                    assert not cut(parts, a, b, clock), \
                        f"trial {t}: cut severed two majority ranks"
            for a in range(n):
                assert not cut(parts, a, a, clock)
        # a fully healed world is fully connected again
        if all(h is not None for (_, h, _) in parts):
            horizon = max(h for (_, h, _) in parts)
            for a in range(n):
                for b in range(n):
                    assert not cut(parts, a, b, horizon), \
                        f"trial {t}: healed cut still drops traffic"
    print(f"partition cuts: {trials}/{trials} trials — symmetric, majority "
          "side fully connected, heals lift every cut")


def election_trials(rng, trials):
    for t in range(trials):
        n = rng.randint(2, 16)
        failed = [False] * n
        barred = [rng.random() < 0.25 for _ in range(n)]
        # cascade: kill the elected coordinator repeatedly — the
        # re-election walks up the rank order deterministically and
        # never picks a corpse, mirroring recover()'s silent-
        # coordinator loop.
        seen = []
        while not all(failed):
            c = elect(failed, barred)
            assert not failed[c], f"trial {t}: elected a dead rank"
            assert c not in seen, f"trial {t}: election cycled"
            live_clear = [r for r in range(n) if not failed[r] and not barred[r]]
            if live_clear:
                assert c == live_clear[0], \
                    f"trial {t}: not the lowest unbarred survivor"
                # a barred (healed-minority) rank never out-elects an
                # unbarred survivor — roothood cannot bounce back.
                assert not barred[c], f"trial {t}: barred rank won election"
            s = successor(failed, barred, c)
            if s is not None:
                assert s != c and not failed[s] and not barred[s], \
                    f"trial {t}: bad successor"
                # the successor is exactly who wins once the root dies,
                # while the barred set is unchanged — custody mirroring
                # targets the right rank.
                probe = list(failed)
                probe[c] = True
                assert elect(probe, barred) == s, \
                    f"trial {t}: successor is not the next electee"
            seen.append(c)
            failed[c] = True
    print(f"leader election: {trials}/{trials} trials — deterministic "
          "lowest-survivor rule, successors line up, rejoiners stay barred")


def main():
    rng = random.Random(0xFA17)
    quorum_restart_trials(rng, 150)
    partition_property_trials(rng, 80)
    election_trials(rng, 120)


if __name__ == "__main__":
    main()
