#!/usr/bin/env python3
"""Cross-simulation of the speed-aware (heterogeneous) diffusion path.

The build container ships no Rust toolchain (EXPERIMENTS.md §Perf
provenance), so — like tools/crosscheck_distributed.py for the
distributed runtime and tools/crosscheck_refactor.py for the
zero-allocation refactor — this script transcribes the decision logic
of the Rust implementation into Python (IEEE-754 doubles, same
operation orders) and asserts the PR's two load-bearing claims
bit-exactly:

  1. **Strict generalization**: with uniform speeds the weighted
     pipeline (normalized-time stage-2 input, time-denominated quota
     floor, sender-time quota consumption in stage 3) produces
     bit-identical quotas, picks, manifests, and object→node maps to a
     transcription of the PRE-heterogeneity algorithm.
  2. **Seq/dist bit-identity survives heterogeneity**: on random speed
     vectors, the distributed protocols (stage-2 per-node virtual
     diffusion with locally normalized load scalars; stage-3
     rank-ordered manifest wavefront with weighted consumption) agree
     with the sequential weighted sweep to the last bit — stage-2 input
     scalars, net flow rows, iteration counts, quota floors, manifests,
     final maps.

Mirrored Rust code:
  - Topology::node_capacity            rust/src/model/topology.rs
  - LbScratch::load_views (node_time)  rust/src/strategies/diffusion/scratch.rs
  - virtual_balance_with               rust/src/strategies/diffusion/virtual_lb.rs
  - distributed::node_load + stage2    rust/src/distributed/{mod,stage2}.rs
  - quota_floor / eff_load /
    select_comm_node                   rust/src/strategies/diffusion/object_selection.rs
  - distributed stage-3 wavefront      rust/src/distributed/stage3.rs

Run: python3 tools/crosscheck_hetero.py
"""
import heapq
import random


def sum_ltr(xs):
    s = 0.0
    for x in xs:
        s += x
    return s


# ------------------------------------------------------------ topology
class Topo:
    """Mirror of model::Topology: contiguous PE numbering, optional
    per-PE speeds (None = uniform), capacity = left-to-right PE-speed
    sum per node."""

    def __init__(self, n_nodes, pes_per_node, speeds=None):
        self.n_nodes = n_nodes
        self.ppn = pes_per_node
        if speeds is not None and all(s == 1.0 for s in speeds):
            speeds = None  # with_pe_speeds canonicalization
        self.speeds = speeds

    def n_pes(self):
        return self.n_nodes * self.ppn

    def is_uniform(self):
        return self.speeds is None

    def node_of_pe(self, pe):
        return pe // self.ppn

    def node_capacity(self, node):
        if self.speeds is None:
            return float(self.ppn)
        cap = 0.0
        for pe in range(node * self.ppn, (node + 1) * self.ppn):
            cap += self.speeds[pe]
        return cap


# ------------------------------------------------- stage-2 input scalars
def seq_stage2_input(topo, loads, mapping):
    """LbScratch::load_views: node_loads accumulated in object order,
    then (heterogeneous only) divided per node by capacity."""
    node_loads = [0.0] * topo.n_nodes
    for o, pe in enumerate(mapping):
        node_loads[topo.node_of_pe(pe)] += loads[o]
    if topo.is_uniform():
        return node_loads
    return [node_loads[i] / topo.node_capacity(i) for i in range(topo.n_nodes)]


def dist_stage2_input(topo, loads, mapping):
    """distributed::node_load per rank: this node's loads accumulated in
    object order, then divided by this node's own capacity."""
    out = []
    for rank in range(topo.n_nodes):
        my = 0.0
        for o, pe in enumerate(mapping):
            if topo.node_of_pe(pe) == rank:
                my += loads[o]
        out.append(my if topo.is_uniform() else my / topo.node_capacity(rank))
    return out


# --------------------------------------------- stage 2 (fixed point) —
# identical transcriptions to crosscheck_distributed.py; the protocols
# are unit-agnostic, heterogeneity only changes the input scalars.
def seq_virtual_balance(adj, loads, tol, max_iters):
    n = len(loads)
    global_avg = sum_ltr(loads) / max(n, 1)
    if global_avg <= 0.0:
        return [[] for _ in range(n)], 0
    alpha = 1.0 / (max(map(len, adj), default=0) + 1)
    own = list(loads)
    recv = [0.0] * n
    net = {}
    iterations = 0
    for it in range(max_iters):
        iterations = it + 1
        cur = [own[i] + recv[i] for i in range(n)]
        sends = []
        for i in range(n):
            want = 0.0
            for j in adj[i]:
                diff = cur[i] - cur[j]
                if diff > 0.0:
                    want += alpha * diff
            if want <= 0.0:
                continue
            scale = own[i] / want if want > own[i] else 1.0
            if scale <= 0.0:
                continue
            for j in adj[i]:
                diff = cur[i] - cur[j]
                if diff > 0.0:
                    sends.append((i, j, alpha * diff * scale))
        moved = 0.0
        for (i, j, amt) in sends:
            own[i] -= amt
            recv[j] += amt
            a, b, sign = (i, j, 1.0) if i < j else (j, i, -1.0)
            net[(a, b)] = net.get((a, b), 0.0) + sign * amt
            moved += amt
        if seq_converged(adj, own, recv, global_avg, tol) or moved <= tol * global_avg * 1e-3:
            break
    flows = [[] for _ in range(n)]
    for a in range(n):
        for b in adj[a]:
            if a >= b:
                continue
            f = net.get((a, b), 0.0)
            if f > 1e-12:
                flows[a].append((b, f))
            elif f < -1e-12:
                flows[b].append((a, -f))
    for row in flows:
        row.sort(key=lambda e: e[0])
    return flows, iterations


def seq_converged(adj, own, recv, global_avg, tol):
    for i in range(len(adj)):
        if not adj[i]:
            continue
        cur_i = own[i] + recv[i]
        lo = hi = cur_i
        for j in adj[i]:
            c = own[j] + recv[j]
            lo = min(lo, c)
            hi = max(hi, c)
        if (hi - lo) / global_avg > tol:
            return False
    return True


def dist_virtual_balance(adj, loads, tol, max_iters):
    """Mirror of stage2::virtual_balance_node across all ranks (see
    crosscheck_distributed.py for the message-order commentary)."""
    n = len(loads)
    total = loads[0] if n else 0.0
    for r in range(1, n):
        total += loads[r]
    global_avg = total / max(n, 1)
    if global_avg <= 0.0:
        return [[] for _ in range(n)], 0
    alpha = 1.0 / (max(map(len, adj), default=0) + 1)
    own = list(loads)
    recv = [0.0] * n
    net = [[0.0] * len(adj[i]) for i in range(n)]
    iterations = [0] * n
    moved_prev = 0.0
    for sweep in range(max_iters):
        cur = [own[i] + recv[i] for i in range(n)]
        if sweep > 0:
            bits = []
            for i in range(n):
                if not adj[i]:
                    bits.append(True)
                    continue
                lo = hi = cur[i]
                for j in adj[i]:
                    lo = min(lo, cur[j])
                    hi = max(hi, cur[j])
                bits.append((hi - lo) / global_avg <= tol)
            if all(bits) or moved_prev <= tol * global_avg * 1e-3:
                break
        for i in range(n):
            iterations[i] = sweep + 1
        amts = []
        movs = []
        for i in range(n):
            a_i = [0.0] * len(adj[i])
            mov_i = []
            want = 0.0
            for j in adj[i]:
                diff = cur[i] - cur[j]
                if diff > 0.0:
                    want += alpha * diff
            if want > 0.0:
                scale = own[i] / want if want > own[i] else 1.0
                if scale > 0.0:
                    for idx, j in enumerate(adj[i]):
                        diff = cur[i] - cur[j]
                        if diff > 0.0:
                            amt = alpha * diff * scale
                            a_i[idx] = amt
                            mov_i.append(amt)
            amts.append(a_i)
            movs.append(mov_i)
        for i in range(n):
            for idx in range(len(adj[i])):
                own[i] -= amts[i][idx]
                net[i][idx] += amts[i][idx]
        for i in range(n):
            for idx, j in enumerate(adj[i]):
                jidx = adj[j].index(i)
                amt = amts[j][jidx]
                recv[i] += amt
                net[i][idx] -= amt
        moved = 0.0
        for r in range(n):
            for amt in movs[r]:
                moved += amt
        moved_prev = moved
    flows = [
        [(j, net[i][idx]) for idx, j in enumerate(adj[i]) if net[i][idx] > 1e-12]
        for i in range(n)
    ]
    return flows, iterations[0] if n else 0


# --------------------------------------------------- stage 3 (weighted)
def heap_push(h, key, tie, obj):
    heapq.heappush(h, (-key, tie, -obj))


def heap_pop(h):
    k, t, o = heapq.heappop(h)
    return -k, t, -o


def quota_floor(topo, loads, mapping):
    """object_selection::quota_floor: raw-load average on uniform
    topologies; average per-node normalized time otherwise."""
    if topo.is_uniform():
        return 0.01 * sum_ltr(loads) / max(topo.n_nodes, 1)
    node_loads = [0.0] * topo.n_nodes
    for o, pe in enumerate(mapping):
        node_loads[topo.node_of_pe(pe)] += loads[o]
    total_time = 0.0
    for node, l in enumerate(node_loads):
        total_time += l / topo.node_capacity(node)
    return 0.01 * total_time / max(topo.n_nodes, 1)


def eff_load(topo, i, load):
    """object_selection::eff_load: time freed at the sender node."""
    if topo.is_uniform():
        return load
    return load / topo.node_capacity(i)


def select_comm_node(topo, graph, loads, node_map, i, row, floor, overfill,
                     by_node, moved, manifest):
    """object_selection::select_comm_node with weighted consumption."""
    targets = sorted([(j, a) for (j, a) in row if a >= floor],
                     key=lambda e: (-e[1], e[0]))
    migrations = 0
    if not targets:
        return 0
    pool = [o for o in by_node[i] if node_map[o] == i and not moved[o]]
    bytes_to_j = {}
    for (j, quota) in targets:
        remaining = quota
        h = []
        bytes_to_j.clear()  # epoch bump
        for o in pool:
            if moved[o] or node_map[o] != i:
                continue
            bj = 0.0
            local = 0.0
            for (p, w) in graph[o]:
                pn = node_map[p]
                if pn == j:
                    bj += w
                elif pn == i:
                    local += w
            bytes_to_j[o] = bj
            heap_push(h, bj, local, o)
        while remaining > 1e-12:
            if not h:
                break
            key, tie, o = heap_pop(h)
            if moved[o] or node_map[o] != i:
                continue
            cur = bytes_to_j[o]
            if abs(cur - key) > 1e-9:
                heap_push(h, cur, tie, o)
                continue
            load = eff_load(topo, i, loads[o])
            if not (remaining > 0.0 and load * (1.0 - overfill) <= remaining):
                continue
            node_map[o] = j
            moved[o] = True
            migrations += 1
            remaining -= load
            manifest.append((o, j))
            for (p, w) in graph[o]:
                if node_map[p] == i and not moved[p] and p in bytes_to_j:
                    bytes_to_j[p] += w
                    heap_push(h, bytes_to_j[p], 0.0, p)
    return migrations


def legacy_select_comm_node(graph, loads, node_map, i, row, floor, overfill,
                            by_node, moved, manifest):
    """The PRE-heterogeneity body: raw-load quota consumption (the
    uniform topology must reduce the weighted body to exactly this)."""
    topo = Topo(len(by_node), 1)  # uniform by construction
    return select_comm_node(topo, graph, loads, node_map, i, row, floor,
                            overfill, by_node, moved, manifest)


def seq_select(topo, graph, loads, node_map0, flows, floor, overfill):
    node_map = list(node_map0)
    moved = [False] * len(loads)
    by_node = [[] for _ in range(topo.n_nodes)]
    for o, nm in enumerate(node_map):
        by_node[nm].append(o)
    manifests = []
    for i in range(topo.n_nodes):
        m = []
        select_comm_node(topo, graph, loads, node_map, i, flows[i], floor,
                         overfill, by_node, moved, m)
        manifests.append(m)
    return node_map, manifests


def dist_select(topo, graph, loads, node_map0, flows, floor, overfill):
    """stage3::select_and_refine_node's wavefront: fresh per-rank
    replicas, lower-rank manifests replayed before picking."""
    manifests = []
    final_maps = []
    n_nodes = topo.n_nodes
    for rank in range(n_nodes):
        node_map = list(node_map0)
        moved = [False] * len(loads)
        by_node = [[] for _ in range(n_nodes)]
        for o, nm in enumerate(node_map):
            by_node[nm].append(o)
        for h in range(rank):
            for (o, dest) in manifests[h]:
                node_map[o] = dest
                moved[o] = True
        m = []
        select_comm_node(topo, graph, loads, node_map, rank, flows[rank],
                         floor, overfill, by_node, moved, m)
        manifests.append(m)
        final_maps.append(node_map)
    for rank in range(n_nodes):
        for h in range(rank + 1, n_nodes):
            for (o, dest) in manifests[h]:
                final_maps[rank][o] = dest
    for rank in range(1, n_nodes):
        assert final_maps[rank] == final_maps[0], f"replica {rank} diverged"
    return final_maps[0], manifests


# ---------------------------------------------------------------- main
def ring_graph(n, h):
    adj = []
    for i in range(n):
        s = set()
        for d in range(1, h + 1):
            s.add((i + d) % n)
            s.add((i - d) % n)
        s.discard(i)
        adj.append(sorted(s))
    return adj


def random_topo(rng, n_nodes, hetero):
    ppn = rng.choice([1, 1, 2, 3])
    speeds = None
    if hetero:
        speeds = [rng.choice([0.25, 0.5, 1.0, 1.5, 2.0, 4.0])
                  for _ in range(n_nodes * ppn)]
        if all(s == 1.0 for s in speeds):
            speeds[0] = 2.0  # force genuine heterogeneity
    return Topo(n_nodes, ppn, speeds)


def random_objects(rng, topo, objs_per_node):
    n = topo.n_nodes * objs_per_node
    # objects initially packed node by node, on each node's first PE
    mapping = [(o // objs_per_node) * topo.ppn for o in range(n)]
    loads = [rng.uniform(0.2, 3.0) for _ in range(n)]
    graph = [[] for _ in range(n)]
    for o in range(n):
        nbr = (o + 1) % n
        w = float(rng.randint(1, 8) * 16)
        graph[o].append((nbr, w))
        graph[nbr].append((o, w))
    for _ in range(n // 3):
        a = rng.randrange(n)
        b = rng.randrange(n)
        if a != b:
            w = float(rng.randint(1, 8) * 16)
            graph[a].append((b, w))
            graph[b].append((a, w))
    for row in graph:
        row.sort()
    return loads, graph, mapping


def main():
    rng = random.Random(0x4E7E)

    # ---- claim 2a: stage-2 input scalars + fixed point, heterogeneous.
    s2_trials = 220
    for t in range(s2_trials):
        n_nodes = rng.randint(2, 20)
        topo = random_topo(rng, n_nodes, hetero=(t % 4 != 3))
        loads, _, mapping = random_objects(rng, topo, rng.randint(2, 8))
        if t % 9 == 0:
            loads = [0.0] * len(loads)  # zero-load short circuit
        seq_in = seq_stage2_input(topo, loads, mapping)
        dist_in = dist_stage2_input(topo, loads, mapping)
        assert seq_in == dist_in, f"stage2 trial {t}: input scalars diverged"
        adj = ring_graph(n_nodes, rng.randint(1, 3))
        tol = rng.choice([0.02, 0.05, 0.2])
        iters = rng.choice([1, 3, 50, 300])
        sf, si = seq_virtual_balance(adj, seq_in, tol, iters)
        df, di = dist_virtual_balance(adj, dist_in, tol, iters)
        assert si == di, f"stage2 trial {t}: iterations {si} != {di}"
        assert sf == df, f"stage2 trial {t}: flows diverged\n{sf}\n{df}"
    print(f"stage2 hetero: {s2_trials}/{s2_trials} trials bit-identical "
          "(input scalars + flows + iterations)")

    # ---- claim 2b: stage-3 weighted picks, seq sweep vs wavefront.
    s3_trials = 200
    for t in range(s3_trials):
        n_nodes = rng.choice([2, 4, 8])
        topo = random_topo(rng, n_nodes, hetero=(t % 4 != 3))
        loads, graph, mapping = random_objects(rng, topo, rng.randint(3, 10))
        node_map0 = [topo.node_of_pe(pe) for pe in mapping]
        adj = ring_graph(n_nodes, 1 if n_nodes <= 4 else 2)
        flows, _ = seq_virtual_balance(
            adj, seq_stage2_input(topo, loads, mapping), 0.05, 200)
        floor = quota_floor(topo, loads, mapping)
        overfill = rng.choice([0.0, 0.5])
        smap, sman = seq_select(topo, graph, loads, node_map0, flows, floor, overfill)
        dmap, dman = dist_select(topo, graph, loads, node_map0, flows, floor, overfill)
        assert smap == dmap, f"stage3 trial {t}: maps diverged"
        assert sman == dman, f"stage3 trial {t}: manifests diverged"
    print(f"stage3 hetero: {s3_trials}/{s3_trials} trials identical "
          "(maps + manifests, weighted consumption)")

    # ---- claim 1: uniform speeds == legacy algorithm, bit for bit.
    uni_trials = 200
    for t in range(uni_trials):
        n_nodes = rng.choice([2, 4, 8])
        ppn = rng.choice([1, 2])
        # explicit all-1.0 speeds: with_pe_speeds canonicalizes to None
        topo = Topo(n_nodes, ppn, [1.0] * (n_nodes * ppn))
        assert topo.is_uniform()
        loads, graph, mapping = random_objects(rng, topo, rng.randint(3, 8))
        node_map0 = [topo.node_of_pe(pe) for pe in mapping]
        # legacy stage-2 input: raw node loads
        legacy_in = [0.0] * n_nodes
        for o, pe in enumerate(mapping):
            legacy_in[topo.node_of_pe(pe)] += loads[o]
        assert seq_stage2_input(topo, loads, mapping) == legacy_in, \
            f"uniform trial {t}: stage-2 input not raw loads"
        adj = ring_graph(n_nodes, 1)
        flows, _ = seq_virtual_balance(adj, legacy_in, 0.05, 200)
        # legacy floor: 1% of average node load from raw object loads
        legacy_floor = 0.01 * sum_ltr(loads) / max(n_nodes, 1)
        floor = quota_floor(topo, loads, mapping)
        assert floor == legacy_floor, f"uniform trial {t}: floor diverged"
        overfill = rng.choice([0.0, 0.5])
        wmap, wman = seq_select(topo, graph, loads, node_map0, flows, floor, overfill)
        # legacy picks: raw-load consumption
        lmap = list(node_map0)
        lmoved = [False] * len(loads)
        lby = [[] for _ in range(n_nodes)]
        for o, nm in enumerate(lmap):
            lby[nm].append(o)
        lman = []
        for i in range(n_nodes):
            m = []
            legacy_select_comm_node(graph, loads, lmap, i, flows[i],
                                    legacy_floor, overfill, lby, lmoved, m)
            lman.append(m)
        assert wmap == lmap, f"uniform trial {t}: weighted != legacy map"
        assert wman == lman, f"uniform trial {t}: weighted != legacy manifests"
    print(f"uniform==legacy: {uni_trials}/{uni_trials} trials bit-identical "
          "(inputs + floors + picks)")


if __name__ == "__main__":
    main()
