#!/usr/bin/env python3
"""Cross-simulation of the distributed LB protocols vs the sequential model.

The build container ships no Rust toolchain (EXPERIMENTS.md §Perf
provenance), so — like tools/crosscheck_refactor.py did for the
zero-allocation refactor — this script mirrors the decision logic of
both implementations in Python (IEEE-754 doubles, same operation
orders) and asserts the distributed protocols' outcomes are bit-equal
to the sequential model's:

  1. stage 2: the per-node virtual-LB protocol (load exchange, local
     transfer application in sender-rank order, DONE-bit reduction with
     the root-reconstructed exact `moved` sum, symmetric per-pair net
     tracking) vs the sequential fixed point of virtual_lb.rs —
     compares net flow rows AND iteration counts bitwise.
  2. stage 3: the rank-ordered manifest wavefront (fresh per-node
     state, lower-rank manifests replayed before picking) vs the
     sequential sweep of object_selection.rs with its shared
     moved/by_node state — compares final object→node maps and
     manifests exactly.

Run: python3 tools/crosscheck_distributed.py
"""
import heapq
import random


# ----------------------------------------------------------------- rng
def ring_graph(n, h):
    adj = []
    for i in range(n):
        s = set()
        for d in range(1, h + 1):
            s.add((i + d) % n)
            s.add((i - d) % n)
        s.discard(i)
        adj.append(sorted(s))
    return adj


# ------------------------------------------------- stage 2: sequential
# Mirrors virtual_balance_with in rust/src/strategies/diffusion/virtual_lb.rs
def seq_virtual_balance(adj, loads, tol, max_iters):
    n = len(loads)
    global_avg = sum_ltr(loads) / max(n, 1)
    if global_avg <= 0.0:
        return [[] for _ in range(n)], 0
    alpha = 1.0 / (max(map(len, adj), default=0) + 1)
    own = list(loads)
    recv = [0.0] * n
    # net flow per unordered pair, stored at smaller endpoint: key (a,b)
    net = {}
    iterations = 0
    for it in range(max_iters):
        iterations = it + 1
        cur = [own[i] + recv[i] for i in range(n)]
        sends = []
        for i in range(n):
            want = 0.0
            for j in adj[i]:
                diff = cur[i] - cur[j]
                if diff > 0.0:
                    want += alpha * diff
            if want <= 0.0:
                continue
            scale = own[i] / want if want > own[i] else 1.0
            if scale <= 0.0:
                continue
            for j in adj[i]:
                diff = cur[i] - cur[j]
                if diff > 0.0:
                    amt = alpha * diff
                    sends.append((i, j, amt * scale))
        moved = 0.0
        for (i, j, amt) in sends:
            own[i] -= amt
            recv[j] += amt
            a, b, sign = (i, j, 1.0) if i < j else (j, i, -1.0)
            net[(a, b)] = net.get((a, b), 0.0) + sign * amt
            moved += amt
        if seq_converged(adj, own, recv, global_avg, tol) or moved <= tol * global_avg * 1e-3:
            break
    flows = [[] for _ in range(n)]
    for a in range(n):
        for b in adj[a]:
            if a >= b:
                continue
            f = net.get((a, b), 0.0)
            if f > 1e-12:
                flows[a].append((b, f))
            elif f < -1e-12:
                flows[b].append((a, -f))
    for row in flows:
        row.sort(key=lambda e: e[0])
    return flows, iterations


def seq_converged(adj, own, recv, global_avg, tol):
    for i in range(len(adj)):
        if not adj[i]:
            continue
        cur_i = own[i] + recv[i]
        lo = hi = cur_i
        for j in adj[i]:
            c = own[j] + recv[j]
            lo = min(lo, c)
            hi = max(hi, c)
        if (hi - lo) / global_avg > tol:
            return False
    return True


def sum_ltr(xs):
    s = 0.0
    for x in xs:
        s += x
    return s


# ------------------------------------------------ stage 2: distributed
# Mirrors virtual_balance_node in rust/src/distributed/stage2.rs: each
# node holds only (own, recv, per-neighbor net); per sweep it exchanges
# load scalars, applies incoming transfers sorted by sender rank, and
# rank 0 reconstructs the exact moved sum from raw per-send amounts in
# (rank, adjacency) order. The stop decision of sweep r happens at the
# top of sweep r+1, as in the protocol.
def dist_virtual_balance(adj, loads, tol, max_iters):
    n = len(loads)
    # setup reduction at rank 0: sum loads ascending by rank
    total = loads[0] if n else 0.0
    for r in range(1, n):
        total += loads[r]
    global_avg = total / max(n, 1)
    if global_avg <= 0.0:
        return [[] for _ in range(n)], 0
    alpha = 1.0 / (max(map(len, adj), default=0) + 1)
    own = list(loads)          # own[i] is node i's private scalar
    recv = [0.0] * n
    net = [[0.0] * len(adj[i]) for i in range(n)]  # node i's view, sign: +i sends
    iterations = [0] * n
    moved_prev = 0.0           # root state
    stopped = False
    for sweep in range(max_iters):
        cur = [own[i] + recv[i] for i in range(n)]  # the LOAD exchange snapshot
        if sweep > 0:
            # per-node conv bits over the freshly exchanged snapshot
            bits = []
            for i in range(n):
                if not adj[i]:
                    bits.append(True)
                    continue
                lo = hi = cur[i]
                for j in adj[i]:
                    lo = min(lo, cur[j])
                    hi = max(hi, cur[j])
                bits.append((hi - lo) / global_avg <= tol)
            stop = all(bits) or moved_prev <= tol * global_avg * 1e-3
            if stop:
                stopped = True
                break
        for i in range(n):
            iterations[i] = sweep + 1
        # each node plans locally (zero amounts are sent but are no-ops)
        amts = []
        movs = []
        for i in range(n):
            a_i = [0.0] * len(adj[i])
            mov_i = []
            want = 0.0
            for idx, j in enumerate(adj[i]):
                diff = cur[i] - cur[j]
                if diff > 0.0:
                    want += alpha * diff
            if want > 0.0:
                scale = own[i] / want if want > own[i] else 1.0
                if scale > 0.0:
                    for idx, j in enumerate(adj[i]):
                        diff = cur[i] - cur[j]
                        if diff > 0.0:
                            amt = alpha * diff * scale
                            a_i[idx] = amt
                            mov_i.append(amt)
            amts.append(a_i)
            movs.append(mov_i)
        # apply own sends in adjacency order
        for i in range(n):
            for idx in range(len(adj[i])):
                own[i] -= amts[i][idx]
                net[i][idx] += amts[i][idx]
        # apply incoming transfers in ascending sender order
        for i in range(n):
            for idx, j in enumerate(adj[i]):  # adj sorted => sender-rank order
                jidx = adj[j].index(i)
                amt = amts[j][jidx]
                recv[i] += amt
                net[i][idx] -= amt
        # root reconstructs moved from raw amounts in (rank, adj) order
        moved = 0.0
        for r in range(n):
            for amt in movs[r]:
                moved += amt
        moved_prev = moved
    assert len(set(iterations)) <= 1 or stopped, "nodes disagree on sweeps"
    flows = []
    for i in range(n):
        row = [(j, net[i][idx]) for idx, j in enumerate(adj[i]) if net[i][idx] > 1e-12]
        flows.append(row)
    return flows, iterations[0] if n else 0


# ------------------------------------------------- stage 3: shared body
# Mirrors select_comm_node in object_selection.rs. BinaryHeap<Entry>
# always pops the cmp-maximum (total order: key desc, tie asc, obj desc
# inverted -> larger obj last), which heapq reproduces with negated
# keys.
def heap_push(h, key, tie, obj):
    heapq.heappush(h, (-key, tie, -obj))


def heap_pop(h):
    k, t, o = heapq.heappop(h)
    return -k, t, -o


def quota_floor(loads, n_nodes):
    return 0.01 * sum_ltr(loads) / max(n_nodes, 1)


def select_comm_node(graph, loads, node_map, i, row, floor, overfill, by_node, moved,
                     manifest):
    targets = sorted(
        [(j, a) for (j, a) in row if a >= floor],
        key=lambda e: (-e[1], e[0]),
    )
    migrations = 0
    if not targets:
        return 0
    pool = [o for o in by_node[i] if node_map[o] == i and not moved[o]]
    bytes_to_j = {}
    for (j, quota) in targets:
        remaining = quota
        h = []
        bytes_to_j.clear()  # epoch bump
        for o in pool:
            if moved[o] or node_map[o] != i:
                continue
            bj = 0.0
            local = 0.0
            for (p, w) in graph[o]:
                pn = node_map[p]
                if pn == j:
                    bj += w
                elif pn == i:
                    local += w
            bytes_to_j[o] = bj
            heap_push(h, bj, local, o)
        while remaining > 1e-12:
            if not h:
                break
            key, tie, o = heap_pop(h)
            if moved[o] or node_map[o] != i:
                continue
            cur = bytes_to_j[o]
            if abs(cur - key) > 1e-9:
                heap_push(h, cur, tie, o)
                continue
            load = loads[o]
            if not (remaining > 0.0 and load * (1.0 - overfill) <= remaining):
                continue
            node_map[o] = j
            moved[o] = True
            migrations += 1
            remaining -= load
            manifest.append((o, j))
            for (p, w) in graph[o]:
                if node_map[p] == i and not moved[p] and p in bytes_to_j:
                    bytes_to_j[p] += w
                    heap_push(h, bytes_to_j[p], 0.0, p)
    return migrations


def seq_select(graph, loads, node_map0, flows, floor, overfill, n_nodes):
    node_map = list(node_map0)
    moved = [False] * len(loads)
    by_node = [[] for _ in range(n_nodes)]
    for o, nm in enumerate(node_map):
        by_node[nm].append(o)
    manifests = []
    for i in range(n_nodes):
        m = []
        select_comm_node(graph, loads, node_map, i, flows[i], floor, overfill,
                         by_node, moved, m)
        manifests.append(m)
    return node_map, manifests


def dist_select(graph, loads, node_map0, flows, floor, overfill, n_nodes):
    """Each 'node' starts from fresh replicas and replays lower-rank
    manifests before picking — the stage-3 wavefront."""
    manifests = []
    final_maps = []
    for rank in range(n_nodes):
        node_map = list(node_map0)           # fresh replica
        moved = [False] * len(loads)
        by_node = [[] for _ in range(n_nodes)]
        for o, nm in enumerate(node_map):
            by_node[nm].append(o)
        for h in range(rank):                # wavefront in
            for (o, dest) in manifests[h]:
                node_map[o] = dest
                moved[o] = True
        m = []
        select_comm_node(graph, loads, node_map, rank, flows[rank], floor,
                         overfill, by_node, moved, m)
        manifests.append(m)
        final_maps.append(node_map)
    # complete every replica with the remaining manifests
    for rank in range(n_nodes):
        for h in range(rank + 1, n_nodes):
            for (o, dest) in manifests[h]:
                final_maps[rank][o] = dest
    for rank in range(1, n_nodes):
        assert final_maps[rank] == final_maps[0], f"replica {rank} diverged"
    return final_maps[0], manifests


# ---------------------------------------------------------------- main
def random_instance(rng, n_nodes, objs_per_node):
    n = n_nodes * objs_per_node
    node_map = [o // objs_per_node for o in range(n)]
    loads = [rng.uniform(0.2, 3.0) for _ in range(n)]
    graph = [[] for _ in range(n)]
    for o in range(n):
        nbr = (o + 1) % n
        w = float(rng.randint(1, 8) * 16)
        graph[o].append((nbr, w))
        graph[nbr].append((o, w))
    for _ in range(n // 3):
        a = rng.randrange(n)
        b = rng.randrange(n)
        if a != b:
            w = float(rng.randint(1, 8) * 16)
            graph[a].append((b, w))
            graph[b].append((a, w))
    for row in graph:
        row.sort()
    return loads, graph, node_map


def main():
    rng = random.Random(0xD15B)

    s2_trials = 200
    for t in range(s2_trials):
        n = rng.randint(2, 24)
        h = rng.randint(1, 3)
        adj = ring_graph(n, h)
        loads = [rng.uniform(0.0, 10.0) for _ in range(n)]
        if t % 7 == 0:
            loads = [0.0] * n  # zero-load short circuit
        if t % 5 == 0:
            adj[rng.randrange(n)] = []  # hmm: must stay symmetric
            adj = symmetrize(adj)
        tol = rng.choice([0.02, 0.05, 0.2])
        iters = rng.choice([1, 3, 50, 300])
        sf, si = seq_virtual_balance(adj, loads, tol, iters)
        df, di = dist_virtual_balance(adj, loads, tol, iters)
        assert si == di, f"stage2 trial {t}: iterations {si} != {di}"
        assert sf == df, f"stage2 trial {t}: flows diverged\n{sf}\n{df}"
    print(f"stage2: {s2_trials}/{s2_trials} trials bit-identical (flows + iterations)")

    s3_trials = 120
    for t in range(s3_trials):
        n_nodes = rng.choice([2, 4, 8])
        loads, graph, node_map = random_instance(rng, n_nodes, rng.randint(3, 10))
        adj = ring_graph(n_nodes, 1 if n_nodes <= 4 else 2)
        sflows, _ = seq_virtual_balance(adj, [sum_ltr([loads[o] for o in range(len(loads)) if node_map[o] == i]) for i in range(n_nodes)], 0.05, 200)
        floor = quota_floor(loads, n_nodes)
        overfill = rng.choice([0.0, 0.5])
        smap, sman = seq_select(graph, loads, node_map, sflows, floor, overfill, n_nodes)
        dmap, dman = dist_select(graph, loads, node_map, sflows, floor, overfill, n_nodes)
        assert smap == dmap, f"stage3 trial {t}: maps diverged"
        assert sman == dman, f"stage3 trial {t}: manifests diverged"
    print(f"stage3: {s3_trials}/{s3_trials} trials identical (maps + manifests)")


def symmetrize(adj):
    n = len(adj)
    sets = [set() for _ in range(n)]
    for i in range(n):
        for j in adj[i]:
            if i in (set(adj[j]) if adj[j] else set()):
                sets[i].add(j)
                sets[j].add(i)
    return [sorted(s) for s in sets]


if __name__ == "__main__":
    main()
