#!/usr/bin/env python3
"""Cross-simulate the SIMD/SoA hot-path rewrites' bit-identity claims.

The authoring container has no Rust toolchain, so the arithmetic
identities behind the vectorization PR are verified here in Python
(whose floats are the same IEEE-754 binary64, with identical `+ * -
floor fmod` semantics) before CI compiles the real thing:

  1. grid_charge: the branchless mod-2 wrap `x - 2*floor(x*0.5)` agrees
     bitwise with the `rem_euclid(2.0)` form after the `q*(1 - 2r)`
     fold, for every tested f64 (integers, reals, huge, tiny, signed
     zeros).
  2. stage-3 comm scoring: branchless masked accumulation
     `acc += w * (pn == t)` agrees bitwise with the branchy
     `if pn == j ... elif pn == i ...` loop (non-negative weights, same
     left-to-right order — adding +0.0 is an f64 no-op).
  3. SoA grouping: one counting-sort pass groups objects by node in
     exactly the per-node ascending-id order the seed's filter scans
     produced.
  4. LEB128 varints round-trip across the full u64 range.
  5. the `.lbi` CSR upper-triangle gap encoding round-trips arbitrary
     graphs and re-encodes byte-identically.

Rust twins: `rust/src/apps/pic/init.rs::grid_charge`,
`rust/src/strategies/diffusion/object_selection.rs::score_pool_comm`,
`rust/src/strategies/diffusion/scratch.rs::build_soa`,
`rust/src/model/lbi.rs` — locked compiled-side by
`rust/tests/simd_soa_identity.rs`.
"""

import math
import random
import struct
import sys

TRIALS = 300


def bits(x):
    return struct.pack("<d", x)


def rust_rem_euclid_2(x):
    """Exact emulation of Rust's `x.rem_euclid(2.0)`: `%` in Rust is
    fmod; rem_euclid adds the divisor when the remainder is negative
    (a `-0.0` remainder is NOT negative, so it passes through)."""
    r = math.fmod(x, 2.0)
    return r + 2.0 if r < 0.0 else r


def grid_charge_legacy(x, q):
    return q * (1.0 - 2.0 * rust_rem_euclid_2(x))


def grid_charge_branchless(x, q):
    r = x - 2.0 * float(math.floor(x * 0.5))
    return q * (1.0 - 2.0 * r)


def check_grid_charge(rng):
    pinned = [0.0, -0.0, 1.0, -1.0, 2.0, -2.0, 4.0, -4.0, 0.5, -0.5, 1.5,
              -3.5, 1e15, -1e15, 1e300, -1e300,
              sys.float_info.min, -sys.float_info.min]
    cases = [(x, q) for x in pinned for q in (1.0, -1.0, 2.5, 1e-3)]
    for _ in range(TRIALS):
        kind = rng.randrange(3)
        if kind == 0:
            x = float(math.floor(rng.uniform(-1e6, 1e6)))
        elif kind == 1:
            x = rng.uniform(-64.0, 64.0)
        else:
            x = rng.uniform(-1.0, 1.0) * 10.0 ** rng.randrange(0, 300)
        cases.append((x, rng.uniform(-4.0, 4.0)))
    for x, q in cases:
        a = grid_charge_legacy(x, q)
        b = grid_charge_branchless(x, q)
        if bits(a) != bits(b):
            return f"grid_charge mismatch at x={x!r} q={q!r}: {a!r} vs {b!r}"
    return None


def check_masked_accumulation(rng):
    for t in range(TRIALS):
        n_nodes = rng.randrange(2, 9)
        i, j = rng.sample(range(n_nodes), 2)
        row = rng.randrange(0, 33)
        pns = [rng.randrange(n_nodes) for _ in range(row)]
        ws = [rng.uniform(0.0, 100.0) for _ in range(row)]
        bj = local = 0.0
        for pn, w in zip(pns, ws):
            if pn == j:
                bj += w
            elif pn == i:
                local += w
        bjm = localm = 0.0
        for pn, w in zip(pns, ws):
            bjm += w * float(pn == j)
            localm += w * float(pn == i)
        if bits(bj) != bits(bjm) or bits(local) != bits(localm):
            return (f"masked accumulation mismatch trial {t}: "
                    f"({bj!r},{local!r}) vs ({bjm!r},{localm!r})")
    return None


def check_counting_sort_grouping(rng):
    for t in range(TRIALS):
        n = rng.randrange(1, 200)
        n_nodes = rng.randrange(1, 9)
        nm = [rng.randrange(n_nodes) for _ in range(n)]
        offsets = [0] * (n_nodes + 1)
        for v in nm:
            offsets[v + 1] += 1
        for k in range(n_nodes):
            offsets[k + 1] += offsets[k]
        objs = [0] * n
        cursor = offsets[:n_nodes]
        cursor = list(cursor)
        for o, v in enumerate(nm):
            objs[cursor[v]] = o
            cursor[v] += 1
        for node in range(n_nodes):
            got = objs[offsets[node]:offsets[node + 1]]
            want = [o for o in range(n) if nm[o] == node]
            if got != want:
                return (f"counting sort trial {t} node {node}: "
                        f"{got} vs {want}")
    return None


def put_varint(buf, v):
    while True:
        byte = v & 0x7F
        v >>= 7
        if v == 0:
            buf.append(byte)
            return
        buf.append(byte | 0x80)


def read_varint(buf, pos):
    v = 0
    shift = 0
    while True:
        if pos >= len(buf):
            raise ValueError("truncated varint")
        byte = buf[pos]
        pos += 1
        if shift >= 64 or (shift == 63 and byte > 1):
            raise ValueError("varint overflow")
        v |= (byte & 0x7F) << shift
        if not byte & 0x80:
            return v, pos
        shift += 7


def check_varints(rng):
    vals = [0, 1, 127, 128, 16383, 16384, 2**32 - 1, 2**64 - 1]
    vals += [rng.randrange(2**64) for _ in range(TRIALS)]
    for v in vals:
        buf = bytearray()
        put_varint(buf, v)
        got, pos = read_varint(bytes(buf), 0)
        if got != v or pos != len(buf):
            return f"varint round-trip failed for {v}"
    return None


def encode_rows(n, rows):
    """`.lbi` CSR section: per object, varint partner count then
    ascending gap-encoded partners (b > o) with f64 weight bits."""
    buf = bytearray()
    for o in range(n):
        upper = [(b, w) for b, w in rows[o] if b > o]
        put_varint(buf, len(upper))
        prev = o
        for b, w in upper:
            put_varint(buf, b - prev - 1)
            buf += bits(w)
            prev = b
    return bytes(buf)


def decode_rows(n, buf):
    edges = []
    pos = 0
    for o in range(n):
        k, pos = read_varint(buf, pos)
        prev = o
        for _ in range(k):
            gap, pos = read_varint(buf, pos)
            b = prev + gap + 1
            if b >= n:
                raise ValueError("partner out of range")
            (w,) = struct.unpack("<d", buf[pos:pos + 8])
            pos += 8
            edges.append((o, b, w))
            prev = b
    if pos != len(buf):
        raise ValueError("trailing bytes")
    return edges


def check_csr_codec(rng):
    for t in range(TRIALS):
        n = rng.randrange(2, 60)
        pairs = set()
        for _ in range(rng.randrange(0, 3 * n)):
            a, b = rng.sample(range(n), 2)
            pairs.add((min(a, b), max(a, b)))
        edges = sorted((a, b, rng.uniform(0.0, 1e6)) for a, b in pairs)
        rows = [[] for _ in range(n)]
        for a, b, w in edges:
            rows[a].append((b, w))
            rows[b].append((a, w))
        for r in rows:
            r.sort()
        wire = encode_rows(n, rows)
        back = decode_rows(n, wire)
        if back != edges:
            return f"CSR codec trial {t}: decoded edges differ"
        rows2 = [[] for _ in range(n)]
        for a, b, w in back:
            rows2[a].append((b, w))
            rows2[b].append((a, w))
        for r in rows2:
            r.sort()
        if encode_rows(n, rows2) != wire:
            return f"CSR codec trial {t}: re-encode not byte-stable"
    return None


def main():
    rng = random.Random(0x51D05EED)
    checks = [
        ("grid_charge branchless identity", check_grid_charge),
        ("masked vs branchy accumulation", check_masked_accumulation),
        ("counting-sort SoA grouping", check_counting_sort_grouping),
        ("LEB128 varint round-trip", check_varints),
        ("CSR upper-triangle gap codec", check_csr_codec),
    ]
    failed = False
    for name, fn in checks:
        err = fn(rng)
        if err:
            print(f"FAIL {name}: {err}")
            failed = True
        else:
            print(f"ok   {name} ({TRIALS}+ trials)")
    if failed:
        return 1
    print("crosscheck_simd: all identities hold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
