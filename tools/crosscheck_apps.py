#!/usr/bin/env python3
"""Cross-simulation of the App-trait driver refactor (PR 3).

The authoring container has no Rust toolchain (see DESIGN.md), so the
bit-identity claims of the redesign are validated the same way PRs 1-2
validated theirs: by re-implementing both arithmetic paths in Python
(IEEE-754 doubles, identical operation order) and asserting exact
equality over randomized trials.

Three claims are checked, mirroring rust/tests/app_refactor.rs and the
seq-vs-dist assertions of rust/tests/distributed.rs:

1. LEGACY vs GENERIC sequential accounting: the pre-refactor PIC driver
   aggregated usize particle counts per PE (iterating particles) and
   merged crossing logs inside the app; the generic driver accumulates
   f64 work units per object and merges in the driver. For integer
   counts both must produce bit-identical per-PE summaries, node work,
   and modeled comm seconds.

2. UNIT RE-EXPANSION (distributed accounting): the root re-expands
   per-rank (from, to, units) crossing counts into per-crossing
   unit_bytes records in rank order, while the sequential recorder saw
   them in event order. With uniform unit bytes, the sort-merge sums
   must agree exactly, for any interleaving.

3. HOTSPOT seq-vs-dist: per-step halo records emitted by the owner of
   each edge's lower endpoint, gathered per rank, must reproduce the
   sequential per-pair aggregates and α-β comm times exactly.

Run: python3 tools/crosscheck_apps.py
"""

import random
import struct

TRIALS = 200


def f64(x):
    """Round-trip through an IEEE double (Python floats already are)."""
    return struct.unpack("<d", struct.pack("<d", x))[0]


def sort_sum_merge(entries):
    """Mirror of model::graph::sort_sum_merge: stable sort by (a, b),
    then left-to-right sums of adjacent duplicates."""
    entries = sorted(entries, key=lambda e: (e[0], e[1]))  # Python sort is stable
    out = []
    for a, b, w in entries:
        if out and out[-1][0] == a and out[-1][1] == b:
            out[-1][2] = f64(out[-1][2] + w)
        else:
            out.append([a, b, w])
    return [tuple(e) for e in out]


class CostTracker:
    """Mirror of simnet::CostTracker."""

    def __init__(self, n_nodes):
        self.n = n_nodes
        self.reset()

    def reset(self):
        self.inter_msgs = [0] * self.n
        self.inter_bytes = [0.0] * self.n
        self.intra_bytes = [0.0] * self.n

    def record(self, frm, to, bytes_):
        if frm == to:
            self.intra_bytes[frm] = f64(self.intra_bytes[frm] + bytes_)
        else:
            self.inter_msgs[frm] += 1
            self.inter_msgs[to] += 1
            self.inter_bytes[frm] = f64(self.inter_bytes[frm] + bytes_)
            self.inter_bytes[to] = f64(self.inter_bytes[to] + bytes_)

    def comm_times(self, alpha, beta, intra_factor):
        return [
            f64(
                f64(f64(alpha * self.inter_msgs[i]) + f64(beta * self.inter_bytes[i]))
                + f64(f64(beta * intra_factor) * self.intra_bytes[i])
            )
            for i in range(self.n)
        ]


def account_step_comm(n_nodes, node_of, obj_to_pe, neighbor_pairs, moved):
    """Mirror of apps::driver::account_step_comm + comm_times."""
    payload = sort_sum_merge([(min(f, t), max(f, t), b) for f, t, b in moved])
    keys = [(a, b) for a, b, _ in payload]
    consumed = [False] * len(payload)
    tracker = CostTracker(n_nodes)
    for a, b in neighbor_pairs:
        n_a = node_of(obj_to_pe[a])
        n_b = node_of(obj_to_pe[b])
        bytes_ = 0.0
        if (a, b) in dict.fromkeys(keys):  # membership; index below
            idx = keys.index((a, b))
            consumed[idx] = True
            bytes_ = payload[idx][2]
        tracker.record(n_a, n_b, bytes_)
    for idx, (a, b, bytes_) in enumerate(payload):
        if consumed[idx]:
            continue
        tracker.record(node_of(obj_to_pe[a]), node_of(obj_to_pe[b]), bytes_)
    return tracker.comm_times(2e-6, 1.0 / 25e9, 0.1)


def check_legacy_vs_generic(rng):
    """Claim 1: legacy usize-per-PE accounting == generic f64-per-object."""
    n_objs = rng.randrange(4, 40)
    n_pes = rng.randrange(2, 9)
    n_nodes = rng.choice([d for d in range(1, n_pes + 1) if n_pes % d == 0])
    pes_per_node = n_pes // n_nodes
    node_of = lambda pe: pe // pes_per_node
    obj_to_pe = [rng.randrange(n_pes) for _ in range(n_objs)]
    n_particles = rng.randrange(1, 2000)
    chare_of = [rng.randrange(n_objs) for _ in range(n_particles)]
    pb = rng.choice([48.0, 80.0, 17.3])  # non-dyadic too: merges stay per-event

    # crossing events in particle order (both sides see the same events)
    events = []
    for _ in range(rng.randrange(0, 200)):
        a, b = rng.randrange(n_objs), rng.randrange(n_objs)
        if a != b:
            events.append((a, b, pb))

    # legacy: app merges events, driver consumes merged; counts as usize
    legacy_moved = sort_sum_merge(events)
    pe_counts = [0] * n_pes
    for c in chare_of:
        pe_counts[obj_to_pe[c]] += 1
    legacy_node = [0] * n_nodes
    for pe, cnt in enumerate(pe_counts):
        legacy_node[node_of(pe)] += cnt
    legacy_pe = [float(c) for c in pe_counts]
    legacy_comm = account_step_comm(
        n_nodes, node_of, obj_to_pe,
        neighbor_pairs(n_objs, rng), legacy_moved,
    )

    # generic: driver merges raw events; work as f64 +1.0 accumulation
    work = [0.0] * n_objs
    for c in chare_of:
        work[c] = f64(work[c] + 1.0)
    generic_pe = [0.0] * n_pes
    generic_node = [0.0] * n_nodes
    for o, pe in enumerate(obj_to_pe):
        generic_pe[pe] = f64(generic_pe[pe] + work[o])
        generic_node[node_of(pe)] = f64(generic_node[node_of(pe)] + work[o])
    generic_moved = sort_sum_merge(events)
    generic_comm = account_step_comm(
        n_nodes, node_of, obj_to_pe,
        neighbor_pairs(n_objs, rng), generic_moved,
    )

    assert legacy_pe == generic_pe, "per-PE work diverged"
    assert [float(c) for c in legacy_node] == generic_node, "node work diverged"
    # comm computed on different neighbor_pairs draws would differ; redo
    # with one shared draw:
    pairs = neighbor_pairs(n_objs, rng)
    assert account_step_comm(n_nodes, node_of, obj_to_pe, pairs, legacy_moved) == \
        account_step_comm(n_nodes, node_of, obj_to_pe, pairs, generic_moved), \
        "modeled comm diverged"
    assert legacy_moved == generic_moved, "merged crossing logs diverged"
    del legacy_comm, generic_comm


def neighbor_pairs(n_objs, rng):
    pairs = set()
    for _ in range(rng.randrange(0, 3 * n_objs)):
        a, b = rng.randrange(n_objs), rng.randrange(n_objs)
        if a != b:
            pairs.add((min(a, b), max(a, b)))
    return sorted(pairs)


def check_unit_reexpansion(rng):
    """Claim 2: rank-ordered unit re-expansion == event-ordered records."""
    n_objs = rng.randrange(4, 30)
    n_ranks = rng.randrange(2, 9)
    ub = rng.choice([48.0, 64.0, 0.1, 17.3])

    # sequential: events in global event order, ub each
    events = []
    owner = {}  # directed pair -> rank that reports it
    for _ in range(rng.randrange(1, 300)):
        a, b = rng.randrange(n_objs), rng.randrange(n_objs)
        if a == b:
            continue
        events.append((a, b, ub))
        owner.setdefault((a, b), rng.randrange(n_ranks))
    seq_recorder = sort_sum_merge(events)

    # distributed: each rank merges its own unit counts, root re-expands
    # in rank order (rank-local merged order inside)
    per_rank = [[] for _ in range(n_ranks)]
    for a, b, _ in events:
        per_rank[owner[(a, b)]].append((a, b, 1))
    root_records = []
    for r in range(n_ranks):
        merged = {}
        order = []
        for a, b, u in sorted(per_rank[r], key=lambda e: (e[0], e[1])):
            if (a, b) not in merged:
                merged[(a, b)] = 0
                order.append((a, b))
            merged[(a, b)] += u
        for a, b in order:
            for _ in range(merged[(a, b)]):
                root_records.append((a, b, ub))
    dist_recorder = sort_sum_merge(root_records)

    assert seq_recorder == dist_recorder, (
        f"recorder merges diverged for ub={ub}: {seq_recorder} vs {dist_recorder}"
    )


def check_hotspot_seq_vs_dist(rng):
    """Claim 3: hotspot halo accounting, sequential vs gathered."""
    nx, ny = rng.randrange(2, 8), rng.randrange(2, 8)
    n_objs = nx * ny
    n_nodes = rng.choice([2, 3, 4])
    obj_to_pe = [rng.randrange(n_nodes) for _ in range(n_objs)]  # flat topo
    node_of = lambda pe: pe
    halo = 64.0
    pairs = neighbor_pairs(n_objs, rng)
    if not pairs:
        return

    # sequential: app appends every pair once per step
    seq_moved = sort_sum_merge([(a, b, halo) for a, b in pairs])
    seq_comm = account_step_comm(n_nodes, node_of, obj_to_pe, pairs, seq_moved)

    # distributed: owner of the lower endpoint reports (a, b, 1 unit);
    # root expands per rank, bytes accumulated per record
    merged_moved = []
    for r in range(n_nodes):
        for a, b in pairs:
            if node_of(obj_to_pe[a]) == r:
                bytes_ = f64(0.0 + halo)  # one unit
                merged_moved.append((a, b, bytes_))
    dist_comm = account_step_comm(n_nodes, node_of, obj_to_pe, pairs, merged_moved)

    assert seq_comm == dist_comm, "hotspot comm seconds diverged"


def main():
    rng = random.Random(0xA993)
    for t in range(TRIALS):
        check_legacy_vs_generic(rng)
        check_unit_reexpansion(rng)
        check_hotspot_seq_vs_dist(rng)
    print(f"crosscheck_apps: {TRIALS} trials x 3 claims OK — legacy-vs-generic "
          "accounting, rank-ordered unit re-expansion, hotspot seq-vs-dist "
          "all bit-equal")


if __name__ == "__main__":
    main()
