#!/usr/bin/env python3
"""Perf-regression gate over difflb-bench-v1 JSON reports.

Compares a candidate bench run (e.g. CI's BENCH_smoke.json) against the
committed baseline (BENCH_hotpaths.json & friends) path-by-path on
`mean_ns` and fails when any shared path regresses by more than the
threshold (default 10%).

Provenance rules (EXPERIMENTS.md §Perf "measured vs projected"):

  * A baseline carrying a top-level `"projected": true` flag was
    hand-estimated in the toolchain-less authoring container, not
    measured. Gating against it would be noise-vs-fiction, so the gate
    REFUSES it: prints an explicit "no measured baseline yet" skip and
    exits 0. The first green `bench-real` CI run on main replaces the
    file with measured numbers (the Rust emitter writes no `projected`
    field), arming the gate automatically.
  * Paths present only in the candidate are new code — reported, never
    failed. Paths present only in the baseline are warned about (a
    bench that silently vanished is suspicious, but machines differ:
    e.g. PJRT paths only exist where artifacts are installed).

Noise handling: per-path tolerance is
    max(threshold, sigma_mult * std_ns / mean_ns)  [baseline noise]
and paths with baseline mean below `--min-ns` are reported but never
failed (a sub-noise-floor path cannot be gated meaningfully). Baselines
predating the `std_ns` field get the plain threshold.

Exit codes: 0 ok/skip, 1 regression (unless --advisory), 2 usage/IO.

Usage:
  python3 tools/bench_gate.py --baseline BENCH_hotpaths.json \
      --candidate BENCH_smoke.json [--threshold 0.10] [--min-ns 1000] \
      [--sigma-mult 3.0] [--advisory]
  python3 tools/bench_gate.py --selftest
"""

import argparse
import json
import sys

DEFAULT_THRESHOLD = 0.10
DEFAULT_MIN_NS = 1000.0
DEFAULT_SIGMA_MULT = 3.0


def load_report(path):
    with open(path, "r", encoding="utf-8") as f:
        doc = json.load(f)
    if doc.get("schema") != "difflb-bench-v1":
        raise ValueError(f"{path}: not a difflb-bench-v1 report")
    paths = {}
    for entry in doc.get("paths", []):
        paths[entry["name"]] = entry
    return doc, paths


def compare(base_doc, base_paths, cand_paths, threshold, min_ns, sigma_mult):
    """Return (regressions, lines) — pure logic, testable by --selftest."""
    lines = []
    regressions = []
    for name in sorted(set(base_paths) | set(cand_paths)):
        b = base_paths.get(name)
        c = cand_paths.get(name)
        if b is None:
            lines.append(f"  NEW      {name}: {c['mean_ns']:.0f} ns (no baseline, not gated)")
            continue
        if c is None:
            lines.append(f"  MISSING  {name}: in baseline, absent from candidate (warn only)")
            continue
        bm, cm = float(b["mean_ns"]), float(c["mean_ns"])
        if bm < min_ns:
            lines.append(
                f"  FLOOR    {name}: baseline {bm:.0f} ns < {min_ns:.0f} ns noise floor, not gated"
            )
            continue
        tol = threshold
        if "std_ns" in b and bm > 0:
            tol = max(tol, sigma_mult * float(b["std_ns"]) / bm)
        ratio = cm / bm if bm > 0 else float("inf")
        delta = ratio - 1.0
        verdict = "ok"
        # tiny epsilon keeps exactly-at-threshold ratios (1100/1000 in
        # binary fp is a hair above 1.1) from flapping the gate
        if delta > tol + 1e-9:
            verdict = "REGRESSED"
            regressions.append((name, bm, cm, delta, tol))
        lines.append(
            f"  {verdict:<9}{name}: {bm:.0f} -> {cm:.0f} ns "
            f"({delta:+.1%}, tolerance {tol:.1%})"
        )
    return regressions, lines


def run_gate(args):
    try:
        base_doc, base_paths = load_report(args.baseline)
        _, cand_paths = load_report(args.candidate)
    except (OSError, ValueError, KeyError, json.JSONDecodeError) as e:
        print(f"bench_gate: cannot load reports: {e}", file=sys.stderr)
        return 2

    if base_doc.get("projected"):
        print(
            f"bench_gate: SKIP — {args.baseline} carries \"projected\": true: "
            "no measured baseline yet. The baseline was hand-estimated in the "
            "toolchain-less authoring container; the gate arms automatically "
            "once the bench-real CI job commits a measured run (its emitter "
            "writes no projected field)."
        )
        return 0

    regressions, lines = compare(
        base_doc, base_paths, cand_paths, args.threshold, args.min_ns, args.sigma_mult
    )
    print(f"bench_gate: {args.candidate} vs baseline {args.baseline} "
          f"(threshold {args.threshold:.0%})")
    for line in lines:
        print(line)
    if regressions:
        print(f"bench_gate: {len(regressions)} path(s) regressed beyond tolerance:")
        for name, bm, cm, delta, tol in regressions:
            print(f"  {name}: {bm:.0f} -> {cm:.0f} ns ({delta:+.1%} > {tol:.1%})")
        if args.advisory:
            print("bench_gate: advisory mode — reporting only, not failing the build")
            return 0
        return 1
    print("bench_gate: all gated paths within tolerance")
    return 0


def selftest():
    def rep(projected=False, **paths):
        doc = {"schema": "difflb-bench-v1", "label": "t", "paths": list(paths.values())}
        if projected:
            doc["projected"] = True
        return doc, {p["name"]: p for p in paths.values()}

    base_doc, base = rep(
        a={"name": "a", "mean_ns": 1000.0, "std_ns": 10.0},
        b={"name": "b", "mean_ns": 1000.0, "std_ns": 400.0},
        tiny={"name": "tiny", "mean_ns": 10.0, "std_ns": 1.0},
        gone={"name": "gone", "mean_ns": 5000.0, "std_ns": 5.0},
        old={"name": "old", "mean_ns": 2000.0},  # pre-std_ns baseline entry
    )
    _, cand = rep(
        a={"name": "a", "mean_ns": 1200.0},      # +20% on a quiet path -> regression
        b={"name": "b", "mean_ns": 1900.0},      # +90% but sigma tol = 3*0.4 = 120% -> ok
        tiny={"name": "tiny", "mean_ns": 500.0}, # below noise floor -> not gated
        old={"name": "old", "mean_ns": 2100.0},  # +5% within plain threshold -> ok
        new={"name": "new", "mean_ns": 7.0},     # no baseline -> not gated
    )
    regs, lines = compare(base_doc, base, cand, DEFAULT_THRESHOLD, DEFAULT_MIN_NS,
                          DEFAULT_SIGMA_MULT)
    assert [r[0] for r in regs] == ["a"], regs
    assert any("MISSING  gone" in l for l in lines), lines
    assert any("NEW      new" in l for l in lines), lines
    assert any("FLOOR    tiny" in l for l in lines), lines

    # exactly-at-threshold must not fail (strict >)
    _, cand_edge = rep(a={"name": "a", "mean_ns": 1100.0})
    regs, _ = compare(base_doc, base, cand_edge, DEFAULT_THRESHOLD, DEFAULT_MIN_NS, 0.0)
    assert not regs, regs

    # projected refusal is handled in run_gate; assert the flag survives load shape
    pdoc, _ = rep(projected=True, a={"name": "a", "mean_ns": 1.0})
    assert pdoc.get("projected") is True
    print("bench_gate selftest: ok")
    return 0


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", help="committed baseline BENCH_*.json")
    ap.add_argument("--candidate", help="freshly measured BENCH_*.json")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="relative mean_ns regression tolerance (default 0.10)")
    ap.add_argument("--min-ns", type=float, default=DEFAULT_MIN_NS,
                    help="ignore paths with baseline mean below this (default 1000)")
    ap.add_argument("--sigma-mult", type=float, default=DEFAULT_SIGMA_MULT,
                    help="widen tolerance to this many baseline std_ns (default 3)")
    ap.add_argument("--advisory", action="store_true",
                    help="report regressions but always exit 0")
    ap.add_argument("--selftest", action="store_true",
                    help="run the built-in comparator checks and exit")
    args = ap.parse_args()
    if args.selftest:
        return selftest()
    if not args.baseline or not args.candidate:
        ap.error("--baseline and --candidate are required (or use --selftest)")
    return run_gate(args)


if __name__ == "__main__":
    sys.exit(main())
