//! Distributed-mode quickstart: run the PIC PRK benchmark with
//! node-partitioned particle state and the LB pipeline executing as
//! real message-passing protocols, then run the identical configuration
//! on the sequential driver and show that the distributed system
//! reports the same migrations and modeled communication time.
//!
//! Run: `cargo run --release --example distributed_pic`
//!
//! The same run is available from the CLI:
//! `difflb run-pic --mode distributed --set run.deterministic_loads=true`

use difflb::apps::driver::{run_app, DriverConfig};
use difflb::apps::pic::{Backend, InitMode, PicApp, PicConfig};
use difflb::apps::stencil::Decomposition;
use difflb::distributed::driver::run_pic_distributed;
use difflb::model::Topology;
use difflb::strategies::diffusion::{Diffusion, Variant};
use difflb::strategies::StrategyParams;

fn main() -> anyhow::Result<()> {
    let cfg = PicConfig {
        grid: 128,
        n_particles: 20_000,
        k: 1,
        m: 1,
        init: InitMode::Geometric { rho: 0.9 },
        chares_x: 8,
        chares_y: 8,
        decomp: Decomposition::Striped,
        topo: Topology::flat(8),
        q: 1.0,
        seed: 0x9C,
        particle_bytes: 48.0,
        threads: 2,
    };
    // deterministic_loads: particle counts drive the balancer, so the
    // sequential model and the distributed protocols face the exact
    // same LB problem every round — the equivalence below is bit-level.
    let driver = DriverConfig {
        iters: 30,
        lb_period: 10,
        deterministic_loads: true,
        ..Default::default()
    };
    let params = StrategyParams::default();

    println!("distributed: 8 simulated nodes, real particle exchange + LB protocols...");
    let dist = run_pic_distributed(&cfg, Variant::Communication, params, &driver)?;
    println!("{}", dist.summary_line("dist-diff-comm"));

    println!("sequential : same configuration on the round-synchronous driver...");
    let seq = {
        let mut app = PicApp::new(cfg, Backend::Native)?;
        let strat = Diffusion::communication(params);
        run_app(&mut app, &strat, &driver)?
    };
    println!("{}", seq.summary_line("diff-comm"));

    anyhow::ensure!(dist.verified && seq.verified, "PIC verification failed");
    anyhow::ensure!(
        dist.total_migrations == seq.total_migrations,
        "migration counts diverged: {} vs {}",
        dist.total_migrations,
        seq.total_migrations
    );
    let comm_equal = dist
        .records
        .iter()
        .zip(&seq.records)
        .all(|(d, s)| d.comm_max_s == s.comm_max_s && d.migrations == s.migrations);
    anyhow::ensure!(comm_equal, "per-iteration comm/migration records diverged");
    println!(
        "\nequivalence: {} migrations and every per-iteration modeled comm second \
         identical across both executions — the sequential strategy is a faithful \
         model of the distributed system (compute seconds differ: the distributed \
         run measures genuinely parallel pushes).",
        dist.total_migrations
    );
    Ok(())
}
