//! Quickstart: balance a noisy 2D-stencil workload with
//! communication-aware diffusion and print the paper's metrics.
//!
//! Run: `cargo run --release --example quickstart`

use difflb::apps::stencil::{inject_noise, stencil_2d, Decomposition};
use difflb::model::evaluate_mapping;
use difflb::strategies::{make, StrategyParams};

fn main() -> anyhow::Result<()> {
    // 32x32 objects (chares) tiled over a 4x4 grid of processors, each
    // object's load perturbed by ±40% — the Fig 2 setup, smaller.
    let mut inst = stencil_2d(32, 4, 4, Decomposition::Tiled);
    inject_noise(&mut inst, 0.4, 42);

    let before = evaluate_mapping(&inst, &inst.mapping);
    println!("before LB : {before}");

    // The paper's strategy: 4 neighbors, communication-aware.
    let params = StrategyParams { neighbor_count: 4, ..Default::default() };
    let lb = make("diff-comm", params)?;
    let asg = lb.rebalance(&inst);

    let after = evaluate_mapping(&inst, &asg.mapping);
    println!("after  LB : {after}");

    // What a locality-blind strategy does to the same instance:
    let refine = make("greedy-refine", params)?.rebalance(&inst);
    let r = evaluate_mapping(&inst, &refine.mapping);
    println!("greedy-ref: {r}");

    println!(
        "\ndiffusion kept ext/int at {:.3} (greedy-refine: {:.3}) while \
         improving max/avg {:.3} -> {:.3}",
        after.comm_nodes.ratio(),
        r.comm_nodes.ratio(),
        before.max_avg_node,
        after.max_avg_node,
    );
    Ok(())
}
