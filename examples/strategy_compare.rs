//! Compare every registered strategy on a Table-II-style synthetic
//! workload (3D stencil communication, mod-7 over/underload) and print
//! the paper's three metrics side by side.
//!
//! Run: `cargo run --release --example strategy_compare -- [--pes 32]`

use difflb::apps::stencil::{inject_mod7, stencil_3d};
use difflb::model::evaluate_mapping;
use difflb::strategies::{make, StrategyParams, AVAILABLE};
use difflb::util::args::Parser;
use difflb::util::bench::Table;

fn main() -> anyhow::Result<()> {
    let args = Parser::new("strategy_compare — all strategies on one workload")
        .opt("pes", Some("32"), "number of PEs")
        .opt("side", Some("16"), "3D stencil side (objects = side^3)")
        .opt("neighbors", Some("4"), "diffusion neighbor count K")
        .parse_env();
    let pes: usize = args.usize("pes");
    let side: usize = args.usize("side");

    let mut inst = stencil_3d(side, pes);
    inject_mod7(&mut inst, 3.0, 0.3);
    let initial = evaluate_mapping(&inst, &inst.mapping);

    let params = StrategyParams {
        neighbor_count: args.usize("neighbors"),
        ..Default::default()
    };

    let mut table = Table::new(
        format!("{pes} PEs, {}^3 objects, mod-7 imbalance", side),
        &["strategy", "max/avg", "ext/int", "% migrations", "lb time (ms)"],
    );
    table.rowf(&[
        &"(initial)",
        &format!("{:.2}", initial.max_avg_pe),
        &format!("{:.3}", initial.comm_nodes.ratio()),
        &"-",
        &"-",
    ]);
    for name in AVAILABLE {
        if *name == "none" {
            continue;
        }
        let lb = make(name, params)?;
        let t = std::time::Instant::now();
        let asg = lb.rebalance(&inst);
        let ms = t.elapsed().as_secs_f64() * 1e3;
        let m = evaluate_mapping(&inst, &asg.mapping);
        table.rowf(&[
            name,
            &format!("{:.2}", m.max_avg_pe),
            &format!("{:.3}", m.comm_nodes.ratio()),
            &format!("{:.1}%", m.migration_pct),
            &format!("{ms:.1}"),
        ]);
    }
    println!("{}", table.render());
    Ok(())
}
