//! End-to-end driver (DESIGN.md deliverable): the full three-layer
//! stack on a real workload — PIC PRK particles pushed by the
//! AOT-compiled Pallas kernel through PJRT, chare traffic feeding the
//! communication-aware diffusion balancer, PRK analytic verification at
//! the end, and the paper's headline metrics reported per strategy.
//!
//! Run: `cargo run --release --example pic_prk`
//!   (defaults: 1000x1000 grid, 100k particles, k=2, rho=0.9, 12x12
//!    chares, 4 nodes — the paper's §VI-A configuration)
//! Larger runs: `-- --particles 1000000 --grid 2000 --iters 200`

use std::sync::Arc;

use difflb::apps::driver::{run_app, DriverConfig};
use difflb::apps::pic::{Backend, InitMode, PicApp, PicConfig};
use difflb::apps::stencil::Decomposition;
use difflb::model::Topology;
use difflb::runtime::Engine;
use difflb::simnet::NetModel;
use difflb::strategies::{make, StrategyParams};
use difflb::util::args::Parser;
use difflb::util::bench::Table;
use difflb::util::io::{out_path, CsvWriter};

fn main() -> anyhow::Result<()> {
    let args = Parser::new("pic_prk — end-to-end PIC PRK under load balancing")
        .opt("grid", Some("996"), "grid side L (must divide chare grid; paper: ~1000)")
        .opt("particles", Some("100000"), "number of particles")
        .opt("k", Some("2"), "horizontal speed parameter (2k+1 cells/step)")
        .opt("rho", Some("0.9"), "geometric skew")
        .opt("chares", Some("12"), "chare grid side")
        .opt("nodes", Some("4"), "simulated nodes")
        .opt("iters", Some("100"), "time steps")
        .opt("lb-period", Some("10"), "LB period")
        .opt("backend", Some("auto"), "auto|pjrt|native")
        .parse_env();

    let mk_cfg = || PicConfig {
        grid: args.usize("grid"),
        n_particles: args.usize("particles"),
        k: args.parse_as("k"),
        m: 1,
        init: InitMode::Geometric { rho: args.f64("rho") },
        chares_x: args.usize("chares"),
        chares_y: args.usize("chares"),
        decomp: Decomposition::Striped,
        topo: Topology::flat(args.usize("nodes")),
        q: 1.0,
        seed: 0x9C,
        particle_bytes: 48.0,
        threads: 8,
    };
    let backend = match args.str("backend").as_str() {
        "native" => Backend::Native,
        "pjrt" => Backend::Pjrt(Arc::new(Engine::new()?)),
        _ => match Engine::new() {
            Ok(e) => Backend::Pjrt(Arc::new(e)),
            Err(e) => {
                eprintln!("PJRT unavailable ({e:#}), using native backend");
                Backend::Native
            }
        },
    };
    let driver = DriverConfig {
        iters: args.usize("iters"),
        lb_period: args.usize("lb-period"),
        net: NetModel::default(),
        log_every: 0,
        ..Default::default()
    };

    let mut table = Table::new(
        format!(
            "PIC PRK: {} particles, {}^2 grid, k={}, rho={}, {}^2 chares, {} nodes, LB every {}",
            args.str("particles"),
            args.str("grid"),
            args.str("k"),
            args.str("rho"),
            args.str("chares"),
            args.str("nodes"),
            args.str("lb-period"),
        ),
        &["strategy", "total(s)", "compute(s)", "comm(s)", "lb(s)", "avg max/avg", "migr", "verified"],
    );
    let mut csv = CsvWriter::create(
        out_path("pic_prk_series.csv")?,
        &["strategy", "iter", "work_max_avg", "compute_max_s", "comm_max_s", "lb_s"],
    )?;

    for name in ["none", "greedy-refine", "diff-coord", "diff-comm"] {
        let strat = make(name, StrategyParams::default())?;
        let mut app = PicApp::new(mk_cfg(), backend.clone())?;
        let rep = run_app(&mut app, strat.as_ref(), &driver)?;
        let avg_ratio = rep.records.iter().map(|r| r.work_max_avg).sum::<f64>()
            / rep.records.len() as f64;
        for r in &rep.records {
            csv.row(&[
                &name,
                &r.iter,
                &r.work_max_avg,
                &r.compute_max_s,
                &r.comm_max_s,
                &r.lb_s,
            ])?;
        }
        table.rowf(&[
            &name,
            &format!("{:.3}", rep.total_s),
            &format!("{:.3}", rep.compute_s),
            &format!("{:.4}", rep.comm_s),
            &format!("{:.4}", rep.lb_s),
            &format!("{:.3}", avg_ratio),
            &rep.total_migrations,
            &rep.verified,
        ]);
        anyhow::ensure!(rep.verified, "PRK verification failed under {name}");
    }
    csv.flush()?;
    println!("{}", table.render());
    println!("per-iteration series: out/pic_prk_series.csv");
    println!("PRK verification: SUCCESS under every strategy");
    Ok(())
}
