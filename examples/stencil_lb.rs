//! Stencil-over-time example: repeatedly perturb a 2D stencil
//! workload's loads (as a drifting application would) and rebalance
//! with diffusion each round, rendering the partition after every LB
//! step — reproduces the visual story of Figs 1-2.
//!
//! Run: `cargo run --release --example stencil_lb -- [--rounds 5] [--side 48]`
//! Outputs: `out/stencil_round_<i>.{ppm,svg}`

use difflb::apps::stencil::{inject_noise, stencil_2d, Decomposition};
use difflb::model::{evaluate_mapping, Instance};
use difflb::strategies::{make, StrategyParams};
use difflb::util::args::Parser;
use difflb::util::io::out_path;
use difflb::viz;

fn main() -> anyhow::Result<()> {
    let args = Parser::new("stencil_lb — diffusion LB on a drifting stencil")
        .opt("rounds", Some("5"), "LB rounds")
        .opt("side", Some("48"), "stencil side (objects = side^2)")
        .opt("pes", Some("4"), "PE grid side (PEs = pes^2)")
        .opt("noise", Some("0.4"), "load noise amplitude per round")
        .opt("strategy", Some("diff-comm"), "strategy name")
        .parse_env();
    let rounds: usize = args.usize("rounds");
    let side: usize = args.usize("side");
    let pes: usize = args.usize("pes");
    let noise: f64 = args.f64("noise");

    let mut inst: Instance = stencil_2d(side, pes, pes, Decomposition::Tiled);
    let lb = make(&args.str("strategy"), StrategyParams::default())?;

    let scale = (512 / side).max(4) as f64;
    for round in 0..rounds {
        inject_noise(&mut inst, noise, 1000 + round as u64);
        let before = evaluate_mapping(&inst, &inst.mapping);
        let asg = lb.rebalance(&inst);
        let after = evaluate_mapping(&inst, &asg.mapping);
        println!(
            "round {round}: max/avg {:.3} -> {:.3}, ext/int {:.3} -> {:.3}, migr {:.1}%",
            before.max_avg_node,
            after.max_avg_node,
            before.comm_nodes.ratio(),
            after.comm_nodes.ratio(),
            after.migration_pct
        );
        inst.mapping = asg.mapping;
        let ppm = out_path(&format!("stencil_round_{round}.ppm"))?;
        let svg = out_path(&format!("stencil_round_{round}.svg"))?;
        viz::render_ppm(&inst, &inst.mapping, scale, &ppm)?;
        viz::render_svg(&inst, &inst.mapping, scale, &svg)?;
    }
    println!("wrote out/stencil_round_*.ppm/svg");
    Ok(())
}
